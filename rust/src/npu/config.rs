//! SoC hardware descriptions for the cycle-approximate NPU simulator.
//!
//! The paper's testbeds are the Qualcomm Snapdragon 8 Gen 3 (OnePlus 12) and
//! Snapdragon 8 Elite (OnePlus 13T). Both share the Hexagon NPU architecture
//! of Fig. 3: one HMX matrix core (32×32 tiles), 4–6 HVX vector cores
//! (1024-bit), scalar units, an 8 MB software-managed TCM with a 2 KB burst
//! path to HMX, a 1 MB L2 (128 B access), and a DMA path from DDR.
//!
//! Bandwidth numbers are calibrated to the paper's own microbenchmark
//! (Table 2) and the vendor-claimed peak TOPS (§2.3); the mobile-CPU model
//! is calibrated to the paper's Fig. 5 breakdown (CPU ~10× faster than the
//! NPU at element-wise dequantization, far slower at dense GEMM).

/// Hexagon-style NPU description.
#[derive(Debug, Clone)]
pub struct NpuConfig {
    pub name: &'static str,
    /// NPU core clock in GHz (both HVX and HMX issue at this rate here).
    pub clock_ghz: f64,
    /// Number of HVX vector cores.
    pub hvx_cores: usize,
    /// HVX vector register width in bytes (1024-bit = 128 B).
    pub hvx_vector_bytes: usize,
    /// Hardware thread contexts on the vector/scalar units (§2.3: 4–6).
    pub hvx_contexts: usize,
    /// Total HVX vector registers per context; `n_reg_for_lut` of them can
    /// hold lookup tables in the decode kernel (constraint Eqn. 1).
    pub hvx_vector_regs: usize,
    pub n_reg_for_lut: usize,
    /// HMX matrix-multiply tile (32×32).
    pub hmx_tile: usize,
    /// Peak INT8 matrix throughput in TOPS (2 ops per MAC).
    pub hmx_tops_int8: f64,
    /// FP16 matrix throughput in TOPS (half of INT8 on Hexagon).
    pub hmx_tops_fp16: f64,
    /// TCM capacity in bytes (8 MB) and burst width to HMX (2 KB).
    pub tcm_bytes: usize,
    pub tcm_burst_bytes: usize,
    /// L2 capacity (1 MB) and access width (128 B).
    pub l2_bytes: usize,
    pub l2_access_bytes: usize,
    /// DDR→TCM DMA bandwidth, GB/s (Table 2: 59, thread-independent).
    pub dma_gbps: f64,
    /// DMA setup latency per descriptor, µs.
    pub dma_setup_us: f64,
    /// l2fetch bandwidth at 1 / 4 HVX threads (Table 2: 26 / 32 GB/s).
    pub l2fetch_gbps_1t: f64,
    pub l2fetch_gbps_4t: f64,
    /// Implicit vectorized-load bandwidth at 1 / 4 threads (5 / 20 GB/s).
    pub vload_gbps_1t: f64,
    pub vload_gbps_4t: f64,
    /// VLUT cycles-per-instruction (Table 1: 0.5 — two issue per cycle).
    pub vlut_cpi: f64,
    /// Plain HVX vector-ALU CPI.
    pub valu_cpi: f64,
    /// Scalar float op throughput, ops/cycle — NPUs are "primarily designed
    /// for low power and fast integer operations" (§4.1): float conversion
    /// and math are an order of magnitude slower than on the CPU.
    pub scalar_float_ops_per_cycle: f64,
    /// L2 register-spill round-trip penalty in cycles per 128 B line — the
    /// cost the TCM spill buffer (§4.3) avoids.
    pub l2_spill_cycles_per_line: f64,
    /// TCM access cycles per vector (the spill buffer's cost).
    pub tcm_access_cycles: f64,
}

impl NpuConfig {
    /// OnePlus 12 — Snapdragon 8 Gen 3 (Table 2 numbers were measured on
    /// this device).
    pub fn sd8gen3() -> Self {
        Self {
            name: "SD8Gen3",
            clock_ghz: 1.0,
            hvx_cores: 4,
            hvx_vector_bytes: 128,
            hvx_contexts: 4,
            hvx_vector_regs: 32,
            n_reg_for_lut: 16,
            hmx_tile: 32,
            hmx_tops_int8: 34.0,
            hmx_tops_fp16: 17.0,
            tcm_bytes: 8 << 20,
            tcm_burst_bytes: 2048,
            l2_bytes: 1 << 20,
            l2_access_bytes: 128,
            dma_gbps: 59.0,
            dma_setup_us: 0.2,
            l2fetch_gbps_1t: 26.0,
            l2fetch_gbps_4t: 32.0,
            vload_gbps_1t: 5.0,
            vload_gbps_4t: 20.0,
            vlut_cpi: 0.5,
            valu_cpi: 0.5,
            scalar_float_ops_per_cycle: 5.0,
            l2_spill_cycles_per_line: 40.0,
            tcm_access_cycles: 4.0,
        }
    }

    /// OnePlus 13T — Snapdragon 8 Elite (45 TOPS claim, §2.3; 6 HVX cores,
    /// higher clock).
    pub fn sd8elite() -> Self {
        Self {
            name: "SD8Elite",
            clock_ghz: 1.2,
            hvx_cores: 6,
            hvx_contexts: 6,
            hmx_tops_int8: 45.0,
            hmx_tops_fp16: 22.5,
            dma_gbps: 64.0,
            l2fetch_gbps_1t: 28.0,
            l2fetch_gbps_4t: 35.0,
            vload_gbps_1t: 6.0,
            vload_gbps_4t: 24.0,
            ..Self::sd8gen3()
        }
    }

    /// NPU cycle time in microseconds.
    #[inline]
    pub fn cycle_us(&self) -> f64 {
        1e-3 / self.clock_ghz
    }

    /// Effective l2fetch bandwidth for a given thread count (linear
    /// interpolation between the two measured points, clamped).
    pub fn l2fetch_gbps(&self, threads: usize) -> f64 {
        interp_threads(threads, self.l2fetch_gbps_1t, self.l2fetch_gbps_4t)
    }

    /// Effective vectorized-load bandwidth for a given thread count.
    pub fn vload_gbps(&self, threads: usize) -> f64 {
        interp_threads(threads, self.vload_gbps_1t, self.vload_gbps_4t)
    }
}

fn interp_threads(threads: usize, bw1: f64, bw4: f64) -> f64 {
    let t = threads.clamp(1, 4) as f64;
    bw1 + (bw4 - bw1) * (t - 1.0) / 3.0
}

/// Mobile big-core CPU cluster model, for the llama.cpp / T-MAC /
/// bitnet.cpp / llm.npu-decode baselines.
#[derive(Debug, Clone)]
pub struct CpuConfig {
    pub name: &'static str,
    pub cores: usize,
    pub clock_ghz: f64,
    /// SIMD width in bytes (NEON 128-bit).
    pub simd_bytes: usize,
    /// Dense GEMM throughput, GOPS (all cores) — <1 TOPS per §2.3.
    pub gemm_gops: f64,
    /// Element-wise dequantization throughput, Gops/s — the CPU is ~10×
    /// faster than the NPU's scalar float path here (Fig. 5).
    pub dequant_gops: f64,
    /// Table-lookup (TBL) throughput for the T-MAC baseline, G-lookups/s.
    pub tbl_glookups: f64,
    /// Memory bandwidth from DDR, GB/s.
    pub mem_gbps: f64,
}

impl CpuConfig {
    /// Snapdragon 8 Gen 3 Kryo big cores (values consistent with the
    /// paper's Fig. 5 CPU-vs-NPU mpGEMV breakdown and llama.cpp-class
    /// decode throughput on this SoC).
    pub fn sd8gen3_cpu() -> Self {
        Self {
            name: "SD8Gen3-CPU",
            cores: 6,
            clock_ghz: 3.0,
            simd_bytes: 16,
            gemm_gops: 500.0,
            dequant_gops: 40.0,
            tbl_glookups: 48.0,
            mem_gbps: 30.0,
        }
    }

    pub fn sd8elite_cpu() -> Self {
        Self {
            name: "SD8Elite-CPU",
            cores: 8,
            clock_ghz: 3.5,
            gemm_gops: 620.0,
            dequant_gops: 50.0,
            tbl_glookups: 60.0,
            mem_gbps: 34.0,
            ..Self::sd8gen3_cpu()
        }
    }
}

/// Power states for the energy model (calibrated to Table 3).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Whole-SoC power with the NPU active and CPUs idle, W (QNN/T-MAN
    /// measure 4.7–5.0 W). This is the compute-bound draw: HVX lookups /
    /// HMX MACs saturated.
    pub npu_active_w: f64,
    /// Whole-SoC power while the NPU is *memory-bound* — DMA streaming
    /// weights/KV from DDR with the compute units mostly stalled. Below
    /// the compute draw: kernel-attributed energy prices each stage of a
    /// [`crate::npu::cost::Breakdown`] on its own rail.
    pub npu_mem_w: f64,
    /// Whole-SoC power with big CPU cores busy, W (bitnet.cpp: 8.22 W).
    pub cpu_active_w: f64,
    /// Hybrid NPU+CPU power (llm.npu prefill: 8.89 W — NPU plus the CPU
    /// cores kept hot for outlier computation).
    pub hybrid_active_w: f64,
    /// Idle floor, W.
    pub idle_w: f64,
}

impl PowerModel {
    pub fn sd8gen3() -> Self {
        Self {
            npu_active_w: 4.9,
            npu_mem_w: 3.6,
            cpu_active_w: 8.2,
            hybrid_active_w: 8.9,
            idle_w: 0.8,
        }
    }
}

/// A full SoC: NPU + CPU + DDR + power.
#[derive(Debug, Clone)]
pub struct SocConfig {
    pub name: &'static str,
    pub npu: NpuConfig,
    pub cpu: CpuConfig,
    pub power: PowerModel,
    /// DDR bandwidth available to the CPU cluster, GB/s.
    pub ddr_gbps: f64,
    /// NPU↔CPU synchronization cost per kernel handoff, µs (the overhead
    /// that sinks llm.npu's decode path, §6.2).
    pub npu_cpu_sync_us: f64,
    /// Device DRAM size in bytes (OnePlus 12: 24 GB, OnePlus 13T: 12 GB) —
    /// used to reproduce llm.npu's OOM on 8B models (§6.3).
    pub dram_bytes: usize,
}

impl SocConfig {
    /// OnePlus 12: Snapdragon 8 Gen 3, 24 GB RAM.
    pub fn oneplus12() -> Self {
        Self {
            name: "OnePlus12-SD8Gen3",
            npu: NpuConfig::sd8gen3(),
            cpu: CpuConfig::sd8gen3_cpu(),
            power: PowerModel::sd8gen3(),
            ddr_gbps: 30.0,
            npu_cpu_sync_us: 120.0,
            dram_bytes: 24 << 30,
        }
    }

    /// OnePlus 13T: Snapdragon 8 Elite, 12 GB RAM.
    pub fn oneplus13t() -> Self {
        Self {
            name: "OnePlus13T-SD8Elite",
            npu: NpuConfig::sd8elite(),
            cpu: CpuConfig::sd8elite_cpu(),
            power: PowerModel::sd8gen3(),
            ddr_gbps: 34.0,
            npu_cpu_sync_us: 110.0,
            dram_bytes: 12 << 30,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elite_is_faster_than_gen3() {
        let g3 = NpuConfig::sd8gen3();
        let el = NpuConfig::sd8elite();
        assert!(el.hmx_tops_int8 > g3.hmx_tops_int8);
        assert!(el.hvx_cores > g3.hvx_cores);
        assert_eq!(el.hmx_tops_int8, 45.0, "§2.3: Elite claims 45 TOPS");
    }

    #[test]
    fn table2_anchor_points() {
        let n = NpuConfig::sd8gen3();
        assert_eq!(n.vload_gbps(1), 5.0);
        assert_eq!(n.vload_gbps(4), 20.0);
        assert_eq!(n.l2fetch_gbps(1), 26.0);
        assert_eq!(n.l2fetch_gbps(4), 32.0);
        // DMA is thread-independent and the fastest path.
        assert!(n.dma_gbps > n.l2fetch_gbps(4));
    }

    #[test]
    fn thread_interp_monotone_and_clamped() {
        let n = NpuConfig::sd8gen3();
        assert!(n.vload_gbps(2) > n.vload_gbps(1));
        assert!(n.vload_gbps(3) < n.vload_gbps(4));
        assert_eq!(n.vload_gbps(8), n.vload_gbps(4));
        assert_eq!(n.vload_gbps(0), n.vload_gbps(1));
    }

    #[test]
    fn npu_floats_are_slow_vs_cpu() {
        // Fig. 5's premise: NPU scalar-float dequant is ~10x slower than CPU.
        let n = NpuConfig::sd8gen3();
        let c = CpuConfig::sd8gen3_cpu();
        let npu_float_gops = n.scalar_float_ops_per_cycle * n.clock_ghz * n.hvx_contexts as f64;
        assert!(c.dequant_gops / npu_float_gops >= 1.5);
    }

    #[test]
    fn power_ordering_matches_table3() {
        let p = PowerModel::sd8gen3();
        assert!(p.npu_active_w < p.cpu_active_w);
        assert!(p.cpu_active_w < p.hybrid_active_w);
        // Memory-bound streaming draws less than saturated compute, more
        // than idle — the rails kernel-attributed energy prices against.
        assert!(p.idle_w < p.npu_mem_w);
        assert!(p.npu_mem_w < p.npu_active_w);
    }

    #[test]
    fn oneplus13t_has_less_ram() {
        assert!(SocConfig::oneplus13t().dram_bytes < SocConfig::oneplus12().dram_bytes);
    }
}
