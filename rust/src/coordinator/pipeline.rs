//! The DMA–Vector–Matrix three-stage software pipeline (paper §4.2, Fig. 9),
//! as a discrete-event simulation over weight tiles.
//!
//! Stage 1 (DMA) streams tile t+1 DDR→TCM while stage 2 (vector cores)
//! dequantizes tile t and stage 3 (HMX) multiplies tile t−1. Each stage is a
//! serially-reusable resource; a tile enters a stage only when (a) the
//! previous stage finished it and (b) the resource is free — exactly the
//! dependence structure of the hand-written NPU pipeline. The TCM budget
//! (3 stages × tile footprint) is validated against Eqn. 4 before running.
//!
//! `run_sequential` executes the same tiles with a global barrier between
//! stages — the baseline arm of the Fig. 17 ablation.

use crate::npu::config::NpuConfig;
use crate::npu::cost::Breakdown;
use crate::npu::memory::TcmBudget;

/// Result of simulating one GEMM's tile stream.
#[derive(Debug, Clone)]
pub struct PipelineRun {
    pub total_us: f64,
    /// Busy time per stage (DMA, vector, matrix).
    pub busy_us: [f64; 3],
    pub tiles: usize,
    /// Peak TCM bytes in flight.
    pub peak_tcm: usize,
}

impl PipelineRun {
    /// Utilization of each stage relative to the makespan.
    pub fn utilization(&self) -> [f64; 3] {
        [
            self.busy_us[0] / self.total_us,
            self.busy_us[1] / self.total_us,
            self.busy_us[2] / self.total_us,
        ]
    }
}

/// Simulate pipelined execution of `tiles` identical tiles whose per-stage
/// latencies are `tile.mem_us`, `tile.dq_us`, `tile.cmp_us`.
/// `tile_bytes` is the TCM footprint of one in-flight tile (quantized source
/// + dequantized destination).
pub fn run_pipelined(
    cfg: &NpuConfig,
    tile: &Breakdown,
    tiles: usize,
    tile_bytes: usize,
) -> Result<PipelineRun, String> {
    // Eqn. 4: three stages of tiles resident at once.
    let mut tcm = TcmBudget::new(cfg);
    tcm.reserve(3 * tile_bytes)
        .map_err(|e| format!("tiling violates Eqn. 4: {e}"))?;

    // dma_free[t]: when the DMA engine can start tile t, etc.
    let mut dma_free = 0.0f64;
    let mut vec_free = 0.0f64;
    let mut mat_free = 0.0f64;
    let mut busy = [0.0f64; 3];
    let mut done_last = 0.0f64;
    for _ in 0..tiles {
        let dma_start = dma_free;
        let dma_done = dma_start + tile.mem_us;
        dma_free = dma_done;
        busy[0] += tile.mem_us;

        let vec_start = dma_done.max(vec_free);
        let vec_done = vec_start + tile.dq_us;
        vec_free = vec_done;
        busy[1] += tile.dq_us;

        let mat_start = vec_done.max(mat_free);
        let mat_done = mat_start + tile.cmp_us;
        mat_free = mat_done;
        busy[2] += tile.cmp_us;

        done_last = mat_done;
    }
    Ok(PipelineRun { total_us: done_last, busy_us: busy, tiles, peak_tcm: 3 * tile_bytes })
}

/// Sequential baseline: all DMA, then all dequant, then all matmul
/// (barrier per stage) — no overlap at all (Fig. 17's "Sequential").
pub fn run_sequential(tile: &Breakdown, tiles: usize, tile_bytes: usize) -> PipelineRun {
    let t = tiles as f64;
    let busy = [tile.mem_us * t, tile.dq_us * t, tile.cmp_us * t];
    PipelineRun {
        total_us: busy.iter().sum(),
        busy_us: busy,
        tiles,
        peak_tcm: tile_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::npu::config::NpuConfig;

    fn tile(mem: f64, dq: f64, cmp: f64) -> Breakdown {
        Breakdown { mem_us: mem, dq_us: dq, cmp_us: cmp, overhead_us: 0.0 }
    }

    #[test]
    fn single_tile_is_sum() {
        let cfg = NpuConfig::sd8gen3();
        let t = tile(10.0, 5.0, 8.0);
        let r = run_pipelined(&cfg, &t, 1, 1024).unwrap();
        assert!((r.total_us - 23.0).abs() < 1e-9);
    }

    #[test]
    fn steady_state_dominated_by_slowest_stage() {
        let cfg = NpuConfig::sd8gen3();
        let t = tile(10.0, 5.0, 8.0);
        let n = 100;
        let r = run_pipelined(&cfg, &t, n, 1024).unwrap();
        // ~ n * max_stage + fill of the other two.
        let expect = 10.0 * n as f64 + 5.0 + 8.0;
        assert!((r.total_us - expect).abs() < 1e-6, "{} vs {expect}", r.total_us);
        // The bottleneck stage is ~fully utilized.
        assert!(r.utilization()[0] > 0.98);
    }

    #[test]
    fn pipelined_beats_sequential() {
        let cfg = NpuConfig::sd8gen3();
        let t = tile(4.0, 3.0, 5.0);
        let n = 64;
        let p = run_pipelined(&cfg, &t, n, 1024).unwrap();
        let s = run_sequential(&t, n, 1024);
        let speedup = s.total_us / p.total_us;
        assert!(speedup > 2.0, "speedup {speedup}");
        // Upper bound: sum/max of stages.
        assert!(speedup <= 12.0 / 5.0 + 1e-9);
    }

    #[test]
    fn pipelined_never_slower_than_sequential() {
        let cfg = NpuConfig::sd8gen3();
        for (a, b, c) in [(1.0, 1.0, 1.0), (10.0, 0.1, 0.1), (0.1, 10.0, 0.1), (2.0, 3.0, 7.0)] {
            let t = tile(a, b, c);
            for n in [1usize, 2, 17] {
                let p = run_pipelined(&cfg, &t, n, 64).unwrap();
                let s = run_sequential(&t, n, 64);
                assert!(
                    p.total_us <= s.total_us + 1e-9,
                    "({a},{b},{c}) n={n}: {} > {}",
                    p.total_us,
                    s.total_us
                );
            }
        }
    }

    #[test]
    fn tcm_overflow_rejected() {
        let cfg = NpuConfig::sd8gen3();
        let t = tile(1.0, 1.0, 1.0);
        // 3 x 4MB > 8MB TCM.
        assert!(run_pipelined(&cfg, &t, 4, 4 << 20).is_err());
    }

    #[test]
    fn busy_times_are_work_conserving() {
        let cfg = NpuConfig::sd8gen3();
        let t = tile(2.0, 3.0, 4.0);
        let r = run_pipelined(&cfg, &t, 10, 1024).unwrap();
        assert!((r.busy_us[0] - 20.0).abs() < 1e-9);
        assert!((r.busy_us[1] - 30.0).abs() < 1e-9);
        assert!((r.busy_us[2] - 40.0).abs() < 1e-9);
    }
}
