//! The T-MAN inference engine: the Layer-3 coordinator that owns the
//! request loop and drives the two execution paths of the unified weight
//! layout — chunked prefill through the matrix-path artifact, token-by-token
//! decoding through the LUT-path artifact — with Python nowhere on the path.
//!
//! Numerics come from the PJRT executables (AOT-lowered JAX + Pallas);
//! on-device latency/energy come from the NPU simulator applied to the
//! model's projection shapes (DESIGN.md §1 explains the substitution).

use crate::coordinator::metrics::{sim_energy_j, PhaseTimer, RequestMetrics};
use crate::kernels::dequant_gemm::tman_gemm_latency_us;
use crate::kernels::lut_gemv::tman_gemv_latency_us;
use crate::model::sampler;
use crate::model::tokenizer;
use crate::npu::config::SocConfig;
use crate::npu::energy::Placement;
use crate::npu::memory::LoadMethod;
use crate::quant::formats::{ActDtype, Granularity, QuantFormat, WeightDtype};
use crate::runtime::artifacts::ArtifactMeta;
use crate::runtime::executor::NpuModelRuntime;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// Decoding configuration for one request.
#[derive(Debug, Clone)]
pub struct GenerateOpts {
    pub max_new_tokens: usize,
    /// 0.0 => greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Stop generation at this byte (e.g. b'\n' ends a line). None = run to
    /// max_new_tokens.
    pub stop_byte: Option<u8>,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        Self { max_new_tokens: 64, temperature: 0.8, top_k: 40, seed: 0, stop_byte: None }
    }
}

/// The serving engine.
pub struct Engine {
    pub runtime: NpuModelRuntime,
    pub soc: SocConfig,
    pub fmt: QuantFormat,
    /// Simulated µs per decode token (projection kernels; context-free part).
    sim_decode_proj_us: f64,
    /// Simulated µs per 128-token prefill chunk (projection kernels).
    sim_prefill_chunk_us: f64,
}

impl Engine {
    /// Load artifacts and prepare the simulator against `soc`.
    pub fn load(artifacts: &Path, soc: SocConfig) -> Result<Self> {
        let runtime = NpuModelRuntime::load(artifacts)
            .with_context(|| format!("loading artifacts from {}", artifacts.display()))?;
        let meta = runtime.meta.clone();
        let fmt = QuantFormat::new(
            if meta.bits == 2 { WeightDtype::Int2 } else { WeightDtype::Int4 },
            ActDtype::Fp16,
            Granularity::PerBlock(meta.block),
        );
        let shapes = Self::proj_shapes(&meta);
        let npu = &soc.npu;
        let mut dec = 0.0;
        let mut pre = 0.0;
        for &(m, k) in &shapes {
            dec += tman_gemv_latency_us(npu, m, k, fmt);
            pre += tman_gemm_latency_us(npu, meta.chunk, m, k, fmt);
        }
        // lm head runs once per token in both phases.
        let head = (meta.vocab, meta.d_model);
        dec += tman_gemv_latency_us(npu, head.0, head.1, fmt);
        pre += tman_gemv_latency_us(npu, head.0, head.1, fmt);
        Ok(Self { runtime, soc, fmt, sim_decode_proj_us: dec, sim_prefill_chunk_us: pre })
    }

    /// All per-layer projection shapes × layers for the loaded model.
    fn proj_shapes(meta: &ArtifactMeta) -> Vec<(usize, usize)> {
        let d = meta.d_model;
        let dkv = meta.d_kv();
        let per_layer =
            [(d, d), (dkv, d), (dkv, d), (d, d), (meta.d_ff, d), (meta.d_ff, d), (d, meta.d_ff)];
        let mut all = Vec::new();
        for _ in 0..meta.n_layers {
            all.extend_from_slice(&per_layer);
        }
        all
    }

    /// Simulated on-device time for one decode step at context length `ctx`.
    pub fn sim_decode_us(&self, ctx: usize) -> f64 {
        let meta = &self.runtime.meta;
        let kv_bytes = 2 * meta.n_layers * ctx * meta.d_kv() * 2;
        self.sim_decode_proj_us + LoadMethod::Dma.transfer_us(&self.soc.npu, kv_bytes, 1)
    }

    /// Simulated on-device time for one prefill chunk ending at `ctx`.
    pub fn sim_prefill_chunk_us(&self, ctx: usize) -> f64 {
        let meta = &self.runtime.meta;
        // Chunk attention ~ chunk x ctx MACs on HMX; small at these sizes.
        let macs = 2.0 * (meta.n_layers * meta.chunk * ctx * meta.d_model) as f64;
        self.sim_prefill_chunk_us + macs / (self.soc.npu.hmx_tops_fp16 * 1e6)
    }

    /// Serve one request end to end.
    pub fn generate(&mut self, prompt: &str, opts: &GenerateOpts) -> Result<(String, RequestMetrics)> {
        let meta = self.runtime.meta.clone();
        let prompt_tokens = tokenizer::encode(prompt);
        anyhow::ensure!(!prompt_tokens.is_empty(), "empty prompt");
        let budget = meta.seq.saturating_sub(prompt_tokens.len());
        let max_new = opts.max_new_tokens.min(budget.saturating_sub(1));
        self.runtime.reset()?;

        // ---- prefill: whole chunks through the matrix-path artifact,
        // remainder through the decode path (teacher forcing) ----
        let chunk = meta.chunk;
        let timer = PhaseTimer::start();
        let mut sim_prefill_us = 0.0;
        let mut pos = 0usize;
        let mut logits: Vec<f32> = Vec::new();
        if self.runtime.has_prefill() {
            while prompt_tokens.len() - pos >= chunk {
                let toks: Vec<i32> =
                    prompt_tokens[pos..pos + chunk].iter().map(|&t| t as i32).collect();
                logits = self.runtime.prefill_chunk(&toks, pos as i32)?;
                pos += chunk;
                sim_prefill_us += self.sim_prefill_chunk_us(pos);
            }
        }
        while pos < prompt_tokens.len() {
            logits = self.runtime.decode_step(prompt_tokens[pos] as i32, pos as i32)?;
            sim_prefill_us += self.sim_decode_us(pos + 1);
            pos += 1;
        }
        let wall_prefill_s = timer.stop();

        // ---- decode loop ----
        let timer = PhaseTimer::start();
        let mut sim_decode_us = 0.0;
        let mut rng = Rng::new(opts.seed);
        let mut out_tokens: Vec<usize> = Vec::new();
        for _ in 0..max_new {
            let next = if opts.temperature <= 0.0 {
                sampler::greedy(&logits)
            } else {
                sampler::top_k(&logits, opts.top_k, opts.temperature, &mut rng)
            };
            out_tokens.push(next);
            if Some(next as u8) == opts.stop_byte {
                break;
            }
            logits = self.runtime.decode_step(next as i32, pos as i32)?;
            sim_decode_us += self.sim_decode_us(pos + 1);
            pos += 1;
        }
        let wall_decode_s = timer.stop();

        let pm = &self.soc.power;
        let metrics = RequestMetrics {
            prompt_tokens: prompt_tokens.len(),
            generated_tokens: out_tokens.len(),
            wall_prefill_s,
            wall_decode_s,
            sim_prefill_s: sim_prefill_us / 1e6,
            sim_decode_s: sim_decode_us / 1e6,
            sim_prefill_j: sim_energy_j(pm, Placement::NpuOnly, sim_prefill_us / 1e6, prompt_tokens.len()),
            sim_decode_j: sim_energy_j(pm, Placement::NpuOnly, sim_decode_us / 1e6, out_tokens.len()),
        };
        Ok((tokenizer::decode(&out_tokens), metrics))
    }
}
