//! Fused two-level LUT dequantization (paper §4.1, Fig. 7).
//!
//! Dequantizing bit-serial weights for the prefill GEMM requires three
//! steps, each slow on an NPU when done naively:
//!   1. *bit repacking* (bit-serial → bit-parallel): twelve shift/and/or ops
//!      per 4 weights — replaced by one lookup per bit-plane nibble into a
//!      16-entry **repack LUT** (12× op reduction, §4.1);
//!   2. *integer → float conversion*: slow on integer-oriented NPUs —
//!      replaced by a 16-entry **conversion LUT** indexed by the code;
//!   3. *applying scale / zero-point*: element-wise float multiply-add —
//!      **baked into the conversion-LUT entries**, so building the table
//!      costs `levels` float ops amortized over a whole quantization block
//!      (4 ops per 64/128 weights for INT2: 1/16–1/32 of the naive cost).
//!
//! The same tables are mirrored by the Pallas kernel
//! (`python/compile/kernels/lut_dequant.py`); the Rust side here is both the
//! host-side reference and the simulated-NPU kernel's inner loop.

use crate::quant::bitserial::BitSerialWeights;
use crate::util::f16_round;

/// Level-1 table: repack 4 bit-serial weights into one bit-parallel word.
///
/// For bit position `i`, the 4-bit index `n` (bit `i` of weights w0..w3,
/// LSB = w0) maps to a u16 with bit `w*bits + i` set for every set index bit
/// `w`. OR-ing the looked-up entries over all bit positions reconstructs the
/// 4 codes packed contiguously: weight `w` occupies bits `[w*bits, (w+1)*bits)`.
#[derive(Debug, Clone)]
pub struct RepackLut {
    pub bits: usize,
    /// `tables[i][n]` for bit position `i`, nibble value `n`.
    pub tables: Vec<[u16; 16]>,
}

impl RepackLut {
    pub fn new(bits: usize) -> Self {
        assert!(bits >= 1 && bits <= 4, "repack LUT supports 1..=4 bit weights");
        let tables = (0..bits)
            .map(|i| {
                let mut t = [0u16; 16];
                for (n, entry) in t.iter_mut().enumerate() {
                    let mut e = 0u16;
                    for w in 0..4 {
                        if (n >> w) & 1 == 1 {
                            e |= 1 << (w * bits + i);
                        }
                    }
                    *entry = e;
                }
                t
            })
            .collect();
        Self { bits, tables }
    }

    /// Repack one group of 4 weights. `nibbles[i]` is bit `i` of the 4
    /// weights (one nibble per plane). Returns the bit-parallel word;
    /// code of weight `w` is `(word >> (w*bits)) & mask`.
    #[inline]
    pub fn repack4(&self, nibbles: &[u8]) -> u16 {
        debug_assert_eq!(nibbles.len(), self.bits);
        let mut word = 0u16;
        for (i, &n) in nibbles.iter().enumerate() {
            word |= self.tables[i][(n & 0x0F) as usize];
        }
        word
    }

    /// Extract code `w` (0..4) from a repacked word.
    #[inline]
    pub fn code_of(&self, word: u16, w: usize) -> u8 {
        ((word >> (w * self.bits)) & ((1u16 << self.bits) - 1)) as u8
    }

    /// Lookup operations needed per group of 4 weights (one per bit plane),
    /// vs. the naive shift/and/or count the paper quotes (12 for INT4).
    pub fn ops_per_group(&self) -> (usize, usize) {
        (self.bits, 3 * 4)
    }
}

/// Level-2 table: code → fp16 real value with the group's scale/zero baked
/// in. One table per quantization group; `levels` float ops to build,
/// amortized over the whole block.
#[derive(Debug, Clone)]
pub struct ConvLut {
    /// `entries[c] = fp16((c - zero) * scale)`.
    pub entries: Vec<f32>,
}

impl ConvLut {
    pub fn new(scale: f32, zero: f32, levels: u32) -> Self {
        let entries = (0..levels.max(2)).map(|c| f16_round((c as f32 - zero) * scale)).collect();
        Self { entries }
    }

    #[inline]
    pub fn lookup(&self, code: u8) -> f32 {
        self.entries[code as usize]
    }

    /// Float ops spent building this table (the only float math left in the
    /// fused dequantization path).
    pub fn build_flops(&self) -> usize {
        2 * self.entries.len() // one sub + one mul per entry
    }
}

/// The owned pair of two-level dequantization tables for one weight matrix
/// — the artifact a [`UnifiedLayerPlan`] builds once and keeps for the
/// lifetime of the layer (the real kernel rebuilds conversion LUTs per tile;
/// here the whole matrix's tables are prebuilt). Weight-free: the bit-serial
/// planes are passed in at lookup time, so one owner can hold both the
/// weights and the tables without self-reference.
///
/// [`UnifiedLayerPlan`]: crate::kernels::plan::UnifiedLayerPlan
#[derive(Debug, Clone)]
pub struct DequantTables {
    pub repack: RepackLut,
    /// Conversion LUT per scale group.
    pub conv: Vec<ConvLut>,
}

impl DequantTables {
    /// Build both table levels for `weights`' scale groups and bit width.
    pub fn build(weights: &BitSerialWeights) -> Self {
        let bits = weights.dtype.bits() as usize;
        let levels = 1u32 << bits;
        let conv = weights
            .scales
            .iter()
            .zip(&weights.zeros)
            .map(|(&s, &z)| ConvLut::new(s, z, levels))
            .collect();
        Self { repack: RepackLut::new(bits), conv }
    }

    /// Dequantize K-range `[col0, col0+len)` of `row` into `dst` (fp16-exact
    /// values). `col0` and `len` must be multiples of 4 (the repack group).
    pub fn dequant_row_range(
        &self,
        weights: &BitSerialWeights,
        row: usize,
        col0: usize,
        len: usize,
        dst: &mut [f32],
    ) {
        assert_eq!(col0 % 4, 0, "col0 must be 4-aligned");
        assert_eq!(len % 4, 0, "len must be a multiple of 4");
        assert!(col0 + len <= weights.k.div_ceil(4) * 4);
        assert_eq!(dst.len(), len);
        let bits = self.repack.bits;
        let mut nibbles = vec![0u8; bits];
        for g in 0..len / 4 {
            let nib_idx = col0 / 4 + g;
            for (b, n) in nibbles.iter_mut().enumerate() {
                *n = weights.nibble(b, row, nib_idx);
            }
            let word = self.repack.repack4(&nibbles);
            for w in 0..4 {
                let col = nib_idx * 4 + w;
                if col >= weights.k {
                    break;
                }
                let code = self.repack.code_of(word, w);
                let grp = weights.group_of(row, col);
                dst[g * 4 + w] = self.conv[grp].lookup(code);
            }
        }
    }

    /// Dequantize a full row.
    pub fn dequant_row(&self, weights: &BitSerialWeights, row: usize, dst: &mut [f32]) {
        let k = weights.k;
        if k % 4 == 0 {
            self.dequant_row_range(weights, row, 0, k, dst);
        } else {
            let padded = k.div_ceil(4) * 4;
            let mut tmp = vec![0.0f32; padded];
            self.dequant_row_range(weights, row, 0, padded, &mut tmp);
            dst.copy_from_slice(&tmp[..k]);
        }
    }

    /// Full dequantized (M, K) matrix.
    pub fn dequant_all(&self, weights: &BitSerialWeights) -> Vec<f32> {
        let (m, k) = (weights.m, weights.k);
        let mut out = vec![0.0f32; m * k];
        for i in 0..m {
            let (a, b) = (i * k, (i + 1) * k);
            self.dequant_row(weights, i, &mut out[a..b]);
        }
        out
    }
}

/// Fused two-level dequantizer over a bit-serial weight matrix: the borrowed
/// view binding a weight matrix to its [`DequantTables`].
///
/// Produces exactly what the naive pipeline (repack → int-to-float → affine)
/// produces, but with `bits` LUT ops per 4 weights plus one conversion
/// lookup per weight. Used by the prefill path (vector-core stage of the
/// DMA-Vector-Matrix pipeline) and by the Fig. 16 ablation.
#[derive(Debug)]
pub struct TwoLevelDequant<'a> {
    pub weights: &'a BitSerialWeights,
    pub tables: DequantTables,
}

impl<'a> TwoLevelDequant<'a> {
    pub fn new(weights: &'a BitSerialWeights) -> Self {
        Self { weights, tables: DequantTables::build(weights) }
    }

    /// Dequantize K-range `[col0, col0+len)` of `row` into `dst`.
    pub fn dequant_row_range(&self, row: usize, col0: usize, len: usize, dst: &mut [f32]) {
        self.tables.dequant_row_range(self.weights, row, col0, len, dst);
    }

    /// Dequantize a full row.
    pub fn dequant_row(&self, row: usize, dst: &mut [f32]) {
        self.tables.dequant_row(self.weights, row, dst);
    }

    /// Full dequantized (M, K) matrix.
    pub fn dequant_all(&self) -> Vec<f32> {
        self.tables.dequant_all(self.weights)
    }
}

/// Naive dequantization op counts for one group of 4 `bits`-bit weights —
/// the `ConvertDQ` baseline of Fig. 16. Returns
/// (bit-manipulation ops, int→float conversions, float multiply-adds).
pub fn naive_dequant_ops_per_4(bits: usize) -> (usize, usize, usize) {
    // Per weight: shift + and to extract from the packed word, plus a shift
    // to position (the paper counts "four sets of SHIFT+AND+SHIFT" = 12 ops
    // per 4 weights per bit... per group), then 1 conversion and 1 fma each.
    let _ = bits;
    (12, 4, 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::formats::{Granularity, WeightDtype};
    use crate::quant::quantize::rtn;
    use crate::util::Rng;

    #[test]
    fn paper_repack_example() {
        // §4.1: "the packed value 0b0011, representing the MSB of four INT4
        // weights, is used to fetch the entry 0b0000_0000_1000_1000".
        let lut = RepackLut::new(4);
        assert_eq!(lut.tables[3][0b0011], 0b0000_0000_1000_1000);
    }

    #[test]
    fn repack_reconstructs_codes() {
        let lut = RepackLut::new(4);
        // 4 arbitrary codes.
        let codes = [0x5u8, 0xA, 0x3, 0xF];
        // Build plane nibbles: bit i of each code.
        let nibbles: Vec<u8> = (0..4)
            .map(|i| (0..4).map(|w| ((codes[w] >> i) & 1) << w).fold(0, |a, x| a | x))
            .collect();
        let word = lut.repack4(&nibbles);
        for w in 0..4 {
            assert_eq!(lut.code_of(word, w), codes[w]);
        }
    }

    #[test]
    fn repack_all_int2_combinations() {
        let lut = RepackLut::new(2);
        for c0 in 0..4u8 {
            for c1 in 0..4u8 {
                for c2 in 0..4u8 {
                    for c3 in 0..4u8 {
                        let codes = [c0, c1, c2, c3];
                        let nibbles: Vec<u8> = (0..2)
                            .map(|i| {
                                (0..4).map(|w| ((codes[w] >> i) & 1) << w).fold(0, |a, x| a | x)
                            })
                            .collect();
                        let word = lut.repack4(&nibbles);
                        for w in 0..4 {
                            assert_eq!(lut.code_of(word, w), codes[w]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn conv_lut_bakes_affine() {
        let lut = ConvLut::new(0.25, 8.0, 16);
        assert_eq!(lut.lookup(8), 0.0);
        assert_eq!(lut.lookup(12), 1.0);
        assert_eq!(lut.lookup(0), -2.0);
        assert_eq!(lut.build_flops(), 32);
    }

    #[test]
    fn two_level_matches_reference_dequant() {
        for (dtype, gran) in [
            (WeightDtype::Int4, Granularity::PerBlock(64)),
            (WeightDtype::Int2, Granularity::PerBlock(64)),
            (WeightDtype::Int4, Granularity::PerChannel),
            (WeightDtype::Int2, Granularity::PerTensor),
        ] {
            let (m, k) = (6, 192);
            let w = Rng::new(33).normal_vec(m * k, 0.08);
            let q = rtn(&w, m, k, dtype, gran);
            let bs = BitSerialWeights::from_qmatrix(&q);
            let dq = TwoLevelDequant::new(&bs);
            let got = dq.dequant_all();
            let want = q.dequant_all();
            for (idx, (g, r)) in got.iter().zip(&want).enumerate() {
                // LUT entries are fp16-rounded; reference is f32 product of
                // fp16 scale/zero. Allow half-precision ulp.
                let tol = r.abs().max(1e-3) * 1e-3;
                assert!((g - r).abs() <= tol, "{dtype} {gran} idx {idx}: {g} vs {r}");
            }
        }
    }

    #[test]
    fn two_level_handles_unaligned_k() {
        let (m, k) = (2, 50); // k % 4 != 0
        let w = Rng::new(44).normal_vec(m * k, 0.08);
        let q = rtn(&w, m, k, WeightDtype::Int4, Granularity::PerChannel);
        let bs = BitSerialWeights::from_qmatrix(&q);
        let dq = TwoLevelDequant::new(&bs);
        let got = dq.dequant_all();
        let want = q.dequant_all();
        for (g, r) in got.iter().zip(&want) {
            assert!((g - r).abs() <= r.abs().max(1e-3) * 1e-3);
        }
    }

    #[test]
    fn op_reduction_matches_paper() {
        // One LUT lookup per bit-plane per 4 weights replaces 12 bit ops:
        // 12x for the INT4 repacking step when comparing per-plane work.
        let lut = RepackLut::new(4);
        let (lut_ops, naive_ops) = lut.ops_per_group();
        assert_eq!(lut_ops, 4);
        assert_eq!(naive_ops, 12);
    }
}
