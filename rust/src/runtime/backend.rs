//! Execution backends for the serving engine.
//!
//! The engine's hot path needs exactly three operations — "run one decode
//! step", "run one decode step for every request in a batch" and "run one
//! prefill chunk" — plus per-request KV-cache lifecycle (begin / resume /
//! end). Two implementations provide them:
//!
//! - [`ReferenceBackend`]: the pure-Rust reference transformer over a
//!   paged [`PagedKvPool`] of refcounted KV blocks, addressed by request
//!   id on every call. Always available; this is what the multi-request
//!   serving loop and the CLI run by default. Admission is a token-budget
//!   block reservation (not a slot count); `begin_request_for` resolves
//!   the longest cached prefix of the prompt in the pool's radix index and
//!   returns the hit length, so prefill starts at the hit boundary.
//!   `decode_batch` is a *real* batched step: one shared pass over every
//!   projection's weights advances all requests of the batch together
//!   (`Transformer::forward_batch_lanes`), each lane reading and writing
//!   through its own block table, with per-request logits bit-identical to
//!   sequential single steps.
//! - `Pjrt` (behind the `pjrt` feature): the AOT artifacts executed through
//!   PJRT, single device-resident KV cache (batch 1 on device, no resume,
//!   no prefix reuse).
//!
//! Latency/energy numbers never come from the backend — the engine applies
//! the NPU simulator to the model's [`ModelShape`] either way, so swapping
//! backends changes numerics fidelity, not the performance model.

use crate::kvpool::{KvPoolConfig, KvPoolStats, PagedKvPool};
use crate::model::config::ModelConfig;
use crate::model::transformer::Transformer;
use crate::runtime::artifacts::ArtifactMeta;
use anyhow::Result;

/// The architecture/quantization shape the engine's performance model runs
/// on — the backend-independent subset of [`ArtifactMeta`].
#[derive(Debug, Clone)]
pub struct ModelShape {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    /// Maximum sequence length (prompt + generated).
    pub seq: usize,
    /// Prefill chunk length the matrix path runs at (0 = decode path only).
    pub chunk: usize,
    /// Weight bit width (2 or 4).
    pub bits: u32,
    /// Per-block quantization group size.
    pub block: usize,
}

impl ModelShape {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.d_head()
    }

    pub fn from_config(cfg: &ModelConfig, chunk: usize, bits: u32, block: usize) -> Self {
        Self {
            vocab: cfg.vocab,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            d_ff: cfg.d_ff,
            seq: cfg.max_seq,
            chunk,
            bits,
            block,
        }
    }

    pub fn from_meta(meta: &ArtifactMeta) -> Self {
        Self {
            vocab: meta.vocab,
            d_model: meta.d_model,
            n_layers: meta.n_layers,
            n_heads: meta.n_heads,
            n_kv_heads: meta.n_kv_heads,
            d_ff: meta.d_ff,
            seq: meta.seq,
            chunk: meta.chunk,
            bits: meta.bits,
            block: meta.block,
        }
    }

    /// All per-layer projection (m, k) shapes × layers, in execution order
    /// (q, k, v, o, gate, up, down) — the unit the kernel cost model sums.
    pub fn proj_shapes(&self) -> Vec<(usize, usize)> {
        let d = self.d_model;
        let dkv = self.d_kv();
        let per_layer = [
            (d, d),
            (dkv, d),
            (dkv, d),
            (d, d),
            (self.d_ff, d),
            (self.d_ff, d),
            (d, self.d_ff),
        ];
        let mut all = Vec::with_capacity(per_layer.len() * self.n_layers);
        for _ in 0..self.n_layers {
            all.extend_from_slice(&per_layer);
        }
        all
    }
}

/// One decode step of a batch: (request id, input token, position).
pub type DecodeStep = (u64, i32, i32);

/// Pure-Rust backend: the reference transformer + the paged KV block pool.
/// Every compute call is addressed by request id — there is no single
/// "bound" request, which is what lets a decode batch interleave several
/// requests, a preempted prefill resume against its surviving block table,
/// and a prefix hit share another request's blocks by refcount.
#[derive(Debug, Clone)]
pub struct ReferenceBackend {
    pub model: Transformer,
    pool: PagedKvPool,
}

impl ReferenceBackend {
    /// Legacy fixed-slot geometry: `kv_slots` whole-sequence blocks, no
    /// prefix cache — admission and numerics byte-identical to the old
    /// `KvSlotPool` backend.
    pub fn new(model: Transformer, kv_slots: usize) -> Self {
        let kv = KvPoolConfig::slots(kv_slots, model.cfg.max_seq);
        Self::with_kv(model, kv)
    }

    /// Paged geometry: a block pool of `kv.blocks` × `kv.block_tokens`
    /// positions, optionally with the radix prefix cache.
    pub fn with_kv(model: Transformer, kv: KvPoolConfig) -> Self {
        let pool = PagedKvPool::new(&model.cfg, model.cfg.max_seq, kv);
        Self { model, pool }
    }

    /// Admit `id` with a whole-sequence reservation and no prompt (the
    /// single-shot path). Idempotent per id: re-beginning clears.
    pub fn begin_request(&mut self, id: u64) -> Result<()> {
        let seq = self.model.cfg.max_seq;
        self.begin_request_for(id, &[], seq).map(|_| ())
    }

    /// Admit `id`: reserve blocks for `reserve_tokens` total positions and
    /// resolve the longest cached prefix of `prompt`. Returns the
    /// prefix-hit length — the serving loop starts prefill there, and
    /// positions below it are served from shared blocks. Errors when the
    /// reservation exceeds the pool's free budget.
    pub fn begin_request_for(
        &mut self,
        id: u64,
        prompt: &[usize],
        reserve_tokens: usize,
    ) -> Result<usize> {
        self.pool.begin(id, prompt, reserve_tokens)
    }

    /// Re-attach `id`'s surviving block table after a preemption, contents
    /// intact. Errors if `id` holds no table (it was never admitted or was
    /// released — resuming would silently recompute from nothing).
    pub fn resume_request(&mut self, id: u64) -> Result<()> {
        self.pool.resume(id)
    }

    /// Release `id`'s block table (publishing its prefix into the radix
    /// index when the prefix cache is on).
    pub fn end_request(&mut self, id: u64) {
        self.pool.release(id);
    }

    /// Publish `id`'s whole-block prompt history into the prefix cache
    /// mid-flight (at prefill-complete) without releasing its table.
    /// Returns the number of blocks newly adopted by the index (0 with the
    /// cache off — the pool treats it as a no-op).
    pub fn publish_prefix(&mut self, id: u64) -> Result<usize> {
        self.pool.publish_prefix(id)
    }

    /// The request's prompt tokens served from the prefix cache at
    /// admission.
    pub fn cached_tokens(&self, id: u64) -> usize {
        self.pool.cached_of(id).unwrap_or(0)
    }

    pub fn decode_step(&mut self, id: u64, token: i32, pos: i32) -> Result<Vec<f32>> {
        let vocab = self.model.cfg.vocab;
        anyhow::ensure!(token >= 0 && (token as usize) < vocab, "token {token} out of vocab");
        anyhow::ensure!(pos >= 0, "negative position {pos}");
        self.pool.note_tokens(id, pos as usize, &[token as usize])?;
        let steps = [(token as usize, pos as usize)];
        let mut lanes = self.pool.lanes(&[id])?;
        let mut out = self.model.forward_batch_lanes(&steps, &mut lanes);
        Ok(out.pop().expect("one lane in, one logits vector out"))
    }

    /// One decode step for the whole batch through the *batched* forward:
    /// every linear projection streams its weights once and applies them to
    /// all requests' activations ([`Transformer::forward_batch_lanes`], the
    /// numerics mirror of the batched LUT kernel), while each request's
    /// attention runs against its own block table. Per-request logits are
    /// bit-identical to sequential [`ReferenceBackend::decode_step`] calls.
    pub fn decode_batch(&mut self, steps: &[DecodeStep]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!steps.is_empty(), "empty decode batch");
        let vocab = self.model.cfg.vocab;
        let mut ids = Vec::with_capacity(steps.len());
        let mut lanes_in = Vec::with_capacity(steps.len());
        for (i, &(id, token, pos)) in steps.iter().enumerate() {
            anyhow::ensure!(
                steps[..i].iter().all(|&(prev, _, _)| prev != id),
                "request {id} appears twice in one decode batch"
            );
            anyhow::ensure!(token >= 0 && (token as usize) < vocab, "token {token} out of vocab");
            anyhow::ensure!(pos >= 0, "negative position {pos}");
            // Every id must hold a table *before* anything is recorded —
            // a rejected batch must leave all its valid members usable.
            anyhow::ensure!(
                self.pool.request_len(id).is_some(),
                "request {id} holds no KV table (begin_request missing?)"
            );
            ids.push(id);
            lanes_in.push((token as usize, pos as usize));
        }
        for (&id, &(token, pos)) in ids.iter().zip(&lanes_in) {
            self.pool.note_tokens(id, pos, &[token])?;
        }
        let mut lanes = self.pool.lanes(&ids)?;
        Ok(self.model.forward_batch_lanes(&lanes_in, &mut lanes))
    }

    /// Run one prefill chunk through the *planned* chunk pass
    /// ([`Transformer::forward_chunk_lanes`]): the chunk's positions form
    /// one (n × K) activation block, every projection streams (and, for
    /// planned layers, decodes) its weights once for the whole chunk, and
    /// the returned last-position logits are byte-identical to
    /// teacher-forcing the chunk through
    /// [`ReferenceBackend::decode_step`] one token at a time. On a
    /// prefix-cache hit the serving loop calls this only for the uncached
    /// suffix — attention reads the shared blocks below `pos_base`.
    pub fn prefill_chunk(&mut self, id: u64, tokens: &[i32], pos_base: i32) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "empty prefill chunk");
        anyhow::ensure!(pos_base >= 0, "negative position {pos_base}");
        let vocab = self.model.cfg.vocab;
        let mut toks = Vec::with_capacity(tokens.len());
        for &t in tokens {
            anyhow::ensure!(t >= 0 && (t as usize) < vocab, "token {t} out of vocab");
            toks.push(t as usize);
        }
        self.pool.note_tokens(id, pos_base as usize, &toks)?;
        let mut lanes = self.pool.lanes(&[id])?;
        Ok(self.model.forward_chunk_lanes(&toks, pos_base as usize, &mut lanes))
    }

    /// Requests currently holding a block table.
    pub fn requests_in_use(&self) -> usize {
        self.pool.requests_in_use()
    }

    /// Upper bound on simultaneously admitted requests (each needs at
    /// least one block). Equals the old slot count under the legacy
    /// geometry.
    pub fn max_concurrent(&self) -> usize {
        self.pool.capacity_blocks()
    }

    pub fn kv_stats(&self) -> KvPoolStats {
        self.pool.stats()
    }

    pub fn kv_block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    pub fn kv_reserved_blocks(&self) -> usize {
        self.pool.reserved_blocks()
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.pool.config().prefix_cache
    }

    /// Drop every prefix-cache block reference (with no live requests
    /// this drains the pool to empty).
    pub fn clear_prefix_index(&mut self) {
        self.pool.clear_prefix_index();
    }

    /// Test/diagnostic access to the pool.
    pub fn pool(&self) -> &PagedKvPool {
        &self.pool
    }

    /// Toggle the pool's KV event journal (tracing only; off by default).
    pub fn set_kv_journal(&mut self, on: bool) {
        self.pool.set_journal(on);
    }

    /// Take all KV events journaled since the last drain.
    pub fn drain_kv_journal(&mut self) -> Vec<crate::trace::KvEvent> {
        self.pool.drain_journal()
    }
}

/// The engine's execution backend.
pub enum Backend {
    /// Pure-Rust reference transformer (always available).
    Reference(ReferenceBackend),
    /// PJRT-executed AOT artifacts (requires the `pjrt` feature and a real
    /// xla-rs; the vendored stub errors at runtime).
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::executor::NpuModelRuntime),
}

impl Backend {
    pub fn begin_request(&mut self, id: u64) -> Result<()> {
        match self {
            Backend::Reference(b) => b.begin_request(id),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                let _ = id;
                rt.reset()
            }
        }
    }

    /// Admit a request with its prompt and total token budget; returns the
    /// prefix-cache hit length (0 on the PJRT backend — one device cache,
    /// no sharing).
    pub fn begin_request_for(
        &mut self,
        id: u64,
        prompt: &[usize],
        reserve_tokens: usize,
    ) -> Result<usize> {
        match self {
            Backend::Reference(b) => b.begin_request_for(id, prompt, reserve_tokens),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                let _ = (id, prompt, reserve_tokens);
                rt.reset()?;
                Ok(0)
            }
        }
    }

    /// Re-attach a preempted request's KV state without clearing it. The
    /// PJRT backend's single device cache cannot suspend one request while
    /// serving another, so it cannot resume.
    pub fn resume_request(&mut self, id: u64) -> Result<()> {
        match self {
            Backend::Reference(b) => b.resume_request(id),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => anyhow::bail!(
                "request {id}: resumable preemption needs per-request KV block \
                 tables (reference backend); the PJRT backend has one device cache"
            ),
        }
    }

    pub fn end_request(&mut self, id: u64) {
        match self {
            Backend::Reference(b) => b.end_request(id),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => {
                let _ = id;
            }
        }
    }

    /// Publish a request's prompt blocks into the prefix cache at
    /// prefill-complete (no-op on the PJRT backend — one device cache, no
    /// sharing).
    pub fn publish_request_prefix(&mut self, id: u64) -> Result<usize> {
        match self {
            Backend::Reference(b) => b.publish_prefix(id),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => {
                let _ = id;
                Ok(0)
            }
        }
    }

    /// Whether a full-chunk matrix-path prefill is available.
    pub fn has_prefill(&self) -> bool {
        match self {
            Backend::Reference(_) => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.has_prefill(),
        }
    }

    pub fn decode_step(&mut self, id: u64, token: i32, pos: i32) -> Result<Vec<f32>> {
        match self {
            Backend::Reference(b) => b.decode_step(id, token, pos),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                let _ = id;
                rt.decode_step(token, pos)
            }
        }
    }

    /// One *batched* decode step: a single shared weight pass advances
    /// every `(id, token, pos)` entry, each against its own block table.
    pub fn decode_batch(&mut self, steps: &[DecodeStep]) -> Result<Vec<Vec<f32>>> {
        match self {
            Backend::Reference(b) => b.decode_batch(steps),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                anyhow::ensure!(
                    steps.len() == 1,
                    "the PJRT backend decodes one request at a time ({} batched)",
                    steps.len()
                );
                Ok(vec![rt.decode_step(steps[0].1, steps[0].2)?])
            }
        }
    }

    pub fn prefill_chunk(&mut self, id: u64, tokens: &[i32], pos_base: i32) -> Result<Vec<f32>> {
        match self {
            Backend::Reference(b) => b.prefill_chunk(id, tokens, pos_base),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => {
                let _ = id;
                rt.prefill_chunk(tokens, pos_base)
            }
        }
    }

    /// Requests currently holding KV (1 for the PJRT backend's single
    /// device cache).
    pub fn kv_slots_in_use(&self) -> usize {
        match self {
            Backend::Reference(b) => b.requests_in_use(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => 1,
        }
    }

    /// Upper bound on simultaneously admitted requests: the pool's block
    /// count (every request needs at least one block). Equals the slot
    /// count under the legacy whole-sequence-block geometry.
    pub fn kv_slot_capacity(&self) -> usize {
        match self {
            Backend::Reference(b) => b.max_concurrent(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => 1,
        }
    }

    /// Positions per KV block (`max_seq` for the legacy geometry and the
    /// PJRT backend's single device cache).
    pub fn kv_block_tokens(&self) -> usize {
        match self {
            Backend::Reference(b) => b.kv_block_tokens(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.meta.seq,
        }
    }

    /// Blocks charged against admission right now.
    pub fn kv_reserved_blocks(&self) -> usize {
        match self {
            Backend::Reference(b) => b.kv_reserved_blocks(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => 1,
        }
    }

    /// Pool counters for fleet metrics (zeroed shell on PJRT).
    pub fn kv_stats(&self) -> KvPoolStats {
        match self {
            Backend::Reference(b) => b.kv_stats(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => KvPoolStats {
                capacity_blocks: 1,
                block_tokens: rt.meta.seq,
                ..Default::default()
            },
        }
    }

    /// Toggle the KV event journal (no-op on PJRT — one device cache, no
    /// paged pool to observe).
    pub fn set_kv_journal(&mut self, on: bool) {
        match self {
            Backend::Reference(b) => b.set_kv_journal(on),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => {
                let _ = on;
            }
        }
    }

    /// Take all KV events journaled since the last drain (empty on PJRT).
    pub fn drain_kv_journal(&mut self) -> Vec<crate::trace::KvEvent> {
        match self {
            Backend::Reference(b) => b.drain_kv_journal(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::random_transformer;

    fn backend(kv_slots: usize) -> ReferenceBackend {
        ReferenceBackend::new(random_transformer(&ModelConfig::tiny(), 11), kv_slots)
    }

    #[test]
    fn shape_from_config_matches_dims() {
        let cfg = ModelConfig::tiny();
        let s = ModelShape::from_config(&cfg, 16, 4, 64);
        assert_eq!(s.d_kv(), cfg.d_kv());
        assert_eq!(s.d_head(), cfg.d_head());
        assert_eq!(s.seq, cfg.max_seq);
        assert_eq!(s.proj_shapes().len(), 7 * cfg.n_layers);
        assert!(s.proj_shapes().contains(&(cfg.d_ff, cfg.d_model)));
    }

    #[test]
    fn decode_requires_an_admitted_request() {
        let mut b = backend(1);
        assert!(b.decode_step(1, 65, 0).is_err());
        b.begin_request(1).unwrap();
        let logits = b.decode_step(1, 65, 0).unwrap();
        assert_eq!(logits.len(), b.model.cfg.vocab);
    }

    #[test]
    fn pool_exhaustion_is_an_error_and_release_recovers() {
        let mut b = backend(1);
        b.begin_request(1).unwrap();
        assert!(b.begin_request(2).is_err(), "second request must not fit in one slot");
        b.end_request(1);
        b.begin_request(2).unwrap();
        assert_eq!(b.requests_in_use(), 1);
    }

    #[test]
    fn rebinding_clears_the_cache() {
        let mut b = backend(2);
        b.begin_request(7).unwrap();
        b.decode_step(7, 65, 0).unwrap();
        b.decode_step(7, 66, 1).unwrap();
        // Re-begin the same request: positions restart from 0.
        b.begin_request(7).unwrap();
        let a = b.decode_step(7, 65, 0).unwrap();
        // Fresh request in a fresh table sees identical logits at pos 0.
        b.begin_request(8).unwrap();
        let c = b.decode_step(8, 65, 0).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn resumed_request_continues_where_it_left_off() {
        // Interrupt a request mid-sequence, serve another request, resume:
        // the continuation must match an uninterrupted run token for token.
        let toks = [72i32, 101, 108, 108, 111, 32, 119];
        let mut uninterrupted = backend(2);
        uninterrupted.begin_request(1).unwrap();
        let mut want = Vec::new();
        for (pos, &t) in toks.iter().enumerate() {
            want = uninterrupted.decode_step(1, t, pos as i32).unwrap();
        }

        let mut b = backend(2);
        b.begin_request(1).unwrap();
        for (pos, &t) in toks[..3].iter().enumerate() {
            b.decode_step(1, t, pos as i32).unwrap();
        }
        // Another request churns different blocks while 1 is suspended.
        b.begin_request(2).unwrap();
        b.decode_step(2, 90, 0).unwrap();
        b.end_request(2);
        // Resume does not clear; positions continue at 3.
        b.resume_request(1).unwrap();
        let mut got = Vec::new();
        for (pos, &t) in toks.iter().enumerate().skip(3) {
            got = b.decode_step(1, t, pos as i32).unwrap();
        }
        assert_eq!(got, want, "resumed continuation must match the uninterrupted run");
    }

    #[test]
    fn resume_without_a_table_is_an_error() {
        let mut b = backend(1);
        assert!(b.resume_request(5).is_err(), "never-admitted id must not resume");
        b.begin_request(5).unwrap();
        b.resume_request(5).unwrap();
        b.end_request(5);
        assert!(b.resume_request(5).is_err(), "released id must not resume");
    }

    #[test]
    fn decode_batch_matches_sequential_singles() {
        let mut a = backend(3);
        let mut b = backend(3);
        for id in 1..=3u64 {
            a.begin_request(id).unwrap();
            b.begin_request(id).unwrap();
            // Distinct context per request.
            a.decode_step(id, 64 + id as i32, 0).unwrap();
            b.decode_step(id, 64 + id as i32, 0).unwrap();
        }
        let steps: Vec<DecodeStep> = (1..=3u64).map(|id| (id, 70 + id as i32, 1)).collect();
        let batched = a.decode_batch(&steps).unwrap();
        for (i, &(id, tok, pos)) in steps.iter().enumerate() {
            let solo = b.decode_step(id, tok, pos).unwrap();
            assert_eq!(batched[i], solo, "request {id}");
        }
    }

    #[test]
    fn decode_batch_rejects_duplicate_ids() {
        // Two lanes over one block table would corrupt the cache; the
        // batched forward must refuse before touching anything.
        let mut b = backend(2);
        b.begin_request(1).unwrap();
        assert!(b.decode_batch(&[(1, 65, 0), (1, 66, 0)]).is_err());
        // The table is still usable afterwards.
        assert_eq!(b.decode_batch(&[(1, 65, 0)]).unwrap().len(), 1);
    }

    #[test]
    fn rejected_batch_leaves_valid_members_untouched() {
        // A batch containing a never-admitted id must be refused *before*
        // anything is recorded: the valid member's token record and KV
        // stay pristine, so it can be advanced in a retried batch.
        let mut b = backend(2);
        b.begin_request(1).unwrap();
        assert!(b.decode_batch(&[(1, 65, 0), (2, 66, 0)]).is_err());
        let retried = b.decode_batch(&[(1, 65, 0)]).unwrap();
        assert_eq!(retried.len(), 1, "request 1 must still advance after the rejected batch");
    }

    #[test]
    fn prefill_chunk_matches_stepwise_decode() {
        let mut b = backend(2);
        b.begin_request(1).unwrap();
        let toks = [72i32, 101, 108, 108, 111];
        let chunked = b.prefill_chunk(1, &toks, 0).unwrap();
        b.begin_request(2).unwrap();
        let mut step = Vec::new();
        for (pos, &t) in toks.iter().enumerate() {
            step = b.decode_step(2, t, pos as i32).unwrap();
        }
        assert_eq!(chunked, step);
    }

    #[test]
    fn paged_pool_matches_slot_numerics() {
        // The same token sequence through the legacy whole-sequence-block
        // geometry and a fine-grained paged geometry must produce
        // byte-identical logits: block translation is invisible to the
        // numerics.
        let model = random_transformer(&ModelConfig::tiny(), 11);
        let mut slots = ReferenceBackend::new(model.clone(), 2);
        let paged_cfg = KvPoolConfig::paged(2 * 256 / 8, 8, false);
        let mut paged = ReferenceBackend::with_kv(model, paged_cfg);
        slots.begin_request(1).unwrap();
        paged.begin_request_for(1, &[], 40).unwrap();
        let toks = [72i32, 101, 108, 108, 111, 32, 116, 109, 97, 110];
        let a = slots.prefill_chunk(1, &toks, 0).unwrap();
        let b = paged.prefill_chunk(1, &toks, 0).unwrap();
        assert_eq!(a, b, "chunk logits diverged across KV geometries");
        for pos in 0..4 {
            let x = slots.decode_step(1, 65 + pos, 10 + pos).unwrap();
            let y = paged.decode_step(1, 65 + pos, 10 + pos).unwrap();
            assert_eq!(x, y, "decode step {pos} diverged across KV geometries");
        }
    }

    #[test]
    fn prefix_hit_starts_prefill_at_the_boundary() {
        // Publisher computes a prompt, finishes; an identical prompt hits
        // the cache and its suffix-only prefill lands on byte-identical
        // logits to a cold full prefill.
        let model = random_transformer(&ModelConfig::tiny(), 11);
        let kv = KvPoolConfig::paged(16, 4, true);
        let mut b = ReferenceBackend::with_kv(model.clone(), kv);
        let toks_i32: Vec<i32> = vec![104, 101, 108, 108, 111, 32, 119, 111, 114, 108];
        let prompt: Vec<usize> = toks_i32.iter().map(|&t| t as usize).collect();

        assert_eq!(b.begin_request_for(1, &prompt, 12).unwrap(), 0, "cold cache");
        let cold = b.prefill_chunk(1, &toks_i32, 0).unwrap();
        b.end_request(1);

        let hit = b.begin_request_for(2, &prompt, 12).unwrap();
        assert_eq!(hit, 8, "two full 4-token blocks cached (cap keeps it < prompt)");
        assert_eq!(b.cached_tokens(2), 8);
        // Prefill only the uncached suffix; logits must match the cold run.
        let warm = b.prefill_chunk(2, &toks_i32[hit..], hit as i32).unwrap();
        assert_eq!(warm, cold, "suffix-only prefill diverged from the cold run");
        b.end_request(2);
        assert_eq!(b.kv_stats().prefix_hits, 1);
        assert_eq!(b.kv_stats().prefix_hit_tokens, 8);
    }
}
