//! The paged KV block pool: one arena of fixed-size KV blocks shared by
//! every admitted request, with per-request block tables, refcounted
//! sharing, copy-on-write on divergence, and an optional radix prefix
//! index for cross-request prompt reuse.
//!
//! A *block* holds `block_tokens` consecutive positions of K and V for
//! **all** layers — the unit of allocation, sharing and eviction. A
//! request owns a [`Table`]: logical block index `pos / block_tokens` →
//! physical block id. Admission is by *reservation*: [`PagedKvPool::begin`]
//! reserves the worst-case block count for the request's whole token
//! budget (counting shared blocks as if private), so the pool can always
//! honor an append — copy-on-write and radix eviction happen inside the
//! reserved envelope, never over it.
//!
//! Write protocol (the COW rule): a table may only write a block whose
//! refcount is 1. [`PagedKvPool::append_at`] enforces it — writing a
//! shared block (refcount > 1: another table and/or the prefix index also
//! reference it) first copies the block into a fresh private one and
//! repoints this table. A shared block is therefore immutable for as long
//! as it is shared.
//!
//! The old fixed-slot pool (`KvSlotPool`) reserved `max_seq` tokens per
//! request by construction; that is exactly [`KvPoolConfig::slots`] —
//! `block_tokens = max_seq`, one block per request — so slot semantics are
//! the degenerate case of this pool, not a second code path.

use crate::kvpool::radix::{prefix_block_keys, RadixIndex};
use crate::kvtier::{SpillTier, TierStats};
use crate::model::config::ModelConfig;
use crate::model::kv_cache::KvLanes;
use crate::trace::KvEvent;
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// Pool geometry + prefix-cache switch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Total KV blocks in the pool (the fleet's token budget is
    /// `blocks * block_tokens`).
    pub blocks: usize,
    /// Positions per block (clamped to `max_seq` at pool construction).
    pub block_tokens: usize,
    /// Enable the radix prefix index (prompt reuse across requests).
    pub prefix_cache: bool,
    /// Capacity of the DDR/flash spill tier in blocks (`None` = evictions
    /// drop block contents, the pre-tier behaviour). Requires
    /// `prefix_cache`: the tier rides radix eviction and fault-back.
    pub tier_blocks: Option<usize>,
}

impl KvPoolConfig {
    /// The legacy fixed-slot geometry: one whole-sequence block per
    /// request, no prefix reuse — byte-identical admission and numerics to
    /// the old `KvSlotPool`.
    pub fn slots(n_slots: usize, max_seq: usize) -> Self {
        Self { blocks: n_slots, block_tokens: max_seq, prefix_cache: false, tier_blocks: None }
    }

    /// Paged geometry.
    pub fn paged(blocks: usize, block_tokens: usize, prefix_cache: bool) -> Self {
        Self { blocks, block_tokens, prefix_cache, tier_blocks: None }
    }

    /// Attach a spill tier of `blocks` capacity (builder form).
    #[must_use]
    pub fn with_tier(mut self, blocks: usize) -> Self {
        self.tier_blocks = Some(blocks);
        self
    }

    /// Blocks needed to hold `tokens` positions (min 1 — every admitted
    /// request needs a block for its first append).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens).max(1)
    }
}

/// Pool counters surfaced into fleet metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    pub capacity_blocks: usize,
    pub block_tokens: usize,
    pub blocks_in_use: usize,
    pub blocks_high_water: usize,
    pub requests_in_use: usize,
    /// Bytes of *resident* (allocated) blocks — not theoretical capacity.
    pub resident_bytes: usize,
    pub prefix_lookups: usize,
    pub prefix_hits: usize,
    pub prefix_hit_tokens: usize,
    /// Spill-tier counters (all zero when no tier is configured).
    pub tier: TierStats,
}

/// One request's view of the pool.
#[derive(Debug, Clone)]
struct Table {
    /// Logical block index → physical block id (contiguous from 0).
    blocks: Vec<usize>,
    /// Highest position written + 1 (includes the cached prefix).
    len: usize,
    /// Token ids written (or inherited from a prefix hit) at positions
    /// `0..tokens.len()` — the radix key published on release.
    tokens: Vec<usize>,
    /// Worst-case block reservation admission charged for this request.
    reserved: usize,
    /// Prompt tokens satisfied from the prefix cache at `begin` time.
    cached: usize,
}

/// The refcounted paged KV pool.
#[derive(Debug, Clone)]
pub struct PagedKvPool {
    n_layers: usize,
    dkv: usize,
    max_seq: usize,
    cfg: KvPoolConfig,
    /// K and V arenas: `blocks × n_layers × block_tokens × dkv` floats.
    k: Vec<f32>,
    v: Vec<f32>,
    /// References per block: owning tables + (0 or 1 for) the prefix index.
    refcount: Vec<u32>,
    /// Free physical blocks (stack; bottom = lowest id for determinism).
    free: Vec<usize>,
    tables: Vec<Option<Table>>,
    index: HashMap<u64, usize>,
    /// Sum of live tables' worst-case reservations, in blocks.
    reserved_blocks: usize,
    prefix: Option<RadixIndex>,
    /// DDR/flash spill tier: evicted index blocks move here instead of
    /// being dropped, keyed by cumulative token-prefix hash.
    tier: Option<SpillTier>,
    blocks_high_water: usize,
    prefix_lookups: usize,
    prefix_hits: usize,
    prefix_hit_tokens: usize,
    /// Trace journal: when a traced run is live the pool appends typed
    /// [`KvEvent`]s here (prefix hit, COW, spill, restore, GC) and the
    /// serving loop drains them after each work item, stamping the sim
    /// clock. `None` (the default) records nothing — pool behavior is
    /// identical either way; the journal only *observes*.
    journal: Option<Vec<KvEvent>>,
}

impl PagedKvPool {
    pub fn new(model: &ModelConfig, max_seq: usize, cfg: KvPoolConfig) -> Self {
        assert!(cfg.blocks > 0, "pool needs at least one block");
        assert!(cfg.block_tokens > 0, "block must hold at least one token");
        assert!(
            cfg.tier_blocks.is_none() || cfg.prefix_cache,
            "the spill tier rides radix eviction: tier_blocks requires prefix_cache"
        );
        let cfg = KvPoolConfig { block_tokens: cfg.block_tokens.min(max_seq), ..cfg };
        let dkv = model.d_kv();
        let floats = cfg.blocks * model.n_layers * cfg.block_tokens * dkv;
        Self {
            n_layers: model.n_layers,
            dkv,
            max_seq,
            cfg,
            k: vec![0.0; floats],
            v: vec![0.0; floats],
            refcount: vec![0; cfg.blocks],
            free: (0..cfg.blocks).rev().collect(),
            tables: Vec::new(),
            index: HashMap::new(),
            reserved_blocks: 0,
            prefix: cfg.prefix_cache.then(|| RadixIndex::new(cfg.block_tokens)),
            tier: cfg.tier_blocks.map(SpillTier::new),
            blocks_high_water: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
            journal: None,
        }
    }

    /// Enable (or disable and clear) the trace journal.
    pub fn set_journal(&mut self, on: bool) {
        self.journal = on.then(Vec::new);
    }

    /// Take the journaled events accumulated since the last drain
    /// (empty when the journal is off).
    pub fn drain_journal(&mut self) -> Vec<KvEvent> {
        match &mut self.journal {
            Some(j) => std::mem::take(j),
            None => Vec::new(),
        }
    }

    #[inline]
    fn jot(&mut self, ev: KvEvent) {
        if let Some(j) = &mut self.journal {
            j.push(ev);
        }
    }

    pub fn config(&self) -> KvPoolConfig {
        self.cfg
    }

    pub fn block_tokens(&self) -> usize {
        self.cfg.block_tokens
    }

    pub fn capacity_blocks(&self) -> usize {
        self.cfg.blocks
    }

    pub fn blocks_in_use(&self) -> usize {
        self.cfg.blocks - self.free.len()
    }

    pub fn blocks_high_water(&self) -> usize {
        self.blocks_high_water
    }

    /// Requests currently holding a table.
    pub fn requests_in_use(&self) -> usize {
        self.index.len()
    }

    /// Blocks charged against admission (worst case, shared counted as
    /// private) — the number the scheduler's token-budget admission mirrors.
    pub fn reserved_blocks(&self) -> usize {
        self.reserved_blocks
    }

    /// Bytes of one block (K + V, fp32 host representation).
    fn block_bytes(&self) -> usize {
        2 * self.n_layers * self.cfg.block_tokens * self.dkv * 4
    }

    /// *Resident* footprint: allocated blocks only.
    pub fn bytes(&self) -> usize {
        self.blocks_in_use() * self.block_bytes()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cfg.blocks * self.block_bytes()
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            capacity_blocks: self.cfg.blocks,
            block_tokens: self.cfg.block_tokens,
            blocks_in_use: self.blocks_in_use(),
            blocks_high_water: self.blocks_high_water,
            requests_in_use: self.requests_in_use(),
            resident_bytes: self.bytes(),
            prefix_lookups: self.prefix_lookups,
            prefix_hits: self.prefix_hits,
            prefix_hit_tokens: self.prefix_hit_tokens,
            tier: self.tier.as_ref().map(SpillTier::stats).unwrap_or_default(),
        }
    }

    fn table_of(&self, id: u64) -> Option<usize> {
        self.index.get(&id).copied()
    }

    /// The request's prompt tokens satisfied from the prefix cache.
    pub fn cached_of(&self, id: u64) -> Option<usize> {
        self.table_of(id).map(|ti| self.tables[ti].as_ref().expect("indexed").cached)
    }

    /// The request's current physical block list (tests/diagnostics).
    pub fn request_blocks(&self, id: u64) -> Option<Vec<usize>> {
        self.table_of(id).map(|ti| self.tables[ti].as_ref().expect("indexed").blocks.clone())
    }

    /// Positions written (or inherited) for `id`.
    pub fn request_len(&self, id: u64) -> Option<usize> {
        self.table_of(id).map(|ti| self.tables[ti].as_ref().expect("indexed").len)
    }

    /// Order-independent fingerprint of one physical block's contents —
    /// lets tests prove COW never mutated a shared block.
    pub fn block_fingerprint(&self, phys: usize) -> u64 {
        let floats = self.n_layers * self.cfg.block_tokens * self.dkv;
        let base = phys * floats;
        let mut acc = 0u64;
        for i in 0..floats {
            acc = acc
                .wrapping_mul(0x100_0000_01B3)
                .wrapping_add(u64::from(self.k[base + i].to_bits()))
                .wrapping_mul(0x100_0000_01B3)
                .wrapping_add(u64::from(self.v[base + i].to_bits()));
        }
        acc
    }

    /// Admit `id`: reserve its worst-case block budget for `reserve_tokens`
    /// total positions, resolve the longest cached prefix of
    /// `prompt_tokens` (block-aligned, capped at `prompt - 1` so the last
    /// prompt position is always computed and its logits exist), and bind
    /// the hit blocks by refcount. Re-admitting an id releases the old
    /// table first (the fresh-start "acquire clears" semantics). Returns
    /// the prefix-hit length in tokens.
    pub fn begin(
        &mut self,
        id: u64,
        prompt_tokens: &[usize],
        reserve_tokens: usize,
    ) -> Result<usize> {
        if self.index.contains_key(&id) {
            self.release(id);
        }
        let reserve_tokens = reserve_tokens.max(1).min(self.max_seq);
        let needs = self.cfg.blocks_for(reserve_tokens);
        anyhow::ensure!(
            self.reserved_blocks + needs <= self.cfg.blocks,
            "KV pool over budget: {} of {} blocks reserved, request {id} needs {needs} \
             ({reserve_tokens} tok × {}-token blocks)",
            self.reserved_blocks,
            self.cfg.blocks,
            self.cfg.block_tokens,
        );
        let bt = self.cfg.block_tokens;
        let mut hit = 0usize;
        let mut hit_blocks: Vec<usize> = Vec::new();
        if let Some(radix) = &mut self.prefix {
            self.prefix_lookups += 1;
            hit_blocks = radix.lookup(prompt_tokens);
            // Pin the resident hit path *before* any tier fault-back: the
            // restore loop allocates hot blocks, and allocation may evict —
            // pinned blocks (refcount ≥ 2) are never eviction candidates.
            for &b in &hit_blocks {
                self.refcount[b] += 1;
            }
            self.fault_from_tier(prompt_tokens, &mut hit_blocks);
            hit = (hit_blocks.len() * bt).min(prompt_tokens.len().saturating_sub(1));
            // Keep exactly the blocks covering [0, hit) — when the cap
            // lands mid-block, the last kept block stays shared until the
            // first write COWs it. The lookup returns whole blocks strictly
            // inside the prompt, so the cap can only trim within the last
            // block, never drop one.
            debug_assert_eq!(hit_blocks.len(), hit.div_ceil(bt));
            if hit > 0 {
                self.prefix_hits += 1;
                self.prefix_hit_tokens += hit;
                self.jot(KvEvent::PrefixHit { id, tokens: hit });
            }
        }
        let table = Table {
            blocks: hit_blocks,
            len: hit,
            tokens: prompt_tokens[..hit].to_vec(),
            reserved: needs,
            cached: hit,
        };
        let ti = match self.tables.iter().position(|t| t.is_none()) {
            Some(i) => {
                self.tables[i] = Some(table);
                i
            }
            None => {
                self.tables.push(Some(table));
                self.tables.len() - 1
            }
        };
        self.index.insert(id, ti);
        self.reserved_blocks += needs;
        Ok(hit)
    }

    /// Re-attach a preempted request's table, contents intact. `&mut self`
    /// on purpose: resumption is part of the mutation protocol (the next
    /// append writes through this table), unlike the old slot pool's
    /// `&self` resume.
    pub fn resume(&mut self, id: u64) -> Result<()> {
        anyhow::ensure!(self.index.contains_key(&id), "request {id} holds no KV table to resume");
        Ok(())
    }

    /// Release `id`'s table: publish its whole-block token history into the
    /// prefix index (if enabled), then drop one reference per block. Blocks
    /// that reach refcount 0 return to the free list; blocks adopted by the
    /// index (or shared with another table) survive. Returns whether a
    /// table was held.
    pub fn release(&mut self, id: u64) -> bool {
        let Some(ti) = self.index.remove(&id) else { return false };
        let table = self.tables[ti].take().expect("indexed table present");
        self.reserved_blocks -= table.reserved;
        let bt = self.cfg.block_tokens;
        if let Some(radix) = &mut self.prefix {
            let full = table.tokens.len().min(table.len) / bt;
            if full > 0 {
                let newly = radix.insert(&table.tokens[..full * bt], &table.blocks[..full]);
                for b in newly {
                    self.refcount[b] += 1;
                }
            }
        }
        for &b in &table.blocks {
            self.decref(b);
        }
        self.tier_gc();
        true
    }

    /// Publish `id`'s whole-block token history into the prefix index *now*
    /// — mid-flight, without releasing the table. Called at
    /// prefill-complete so sibling requests (the test-time-compute fork
    /// pattern) can hit the shared prompt while this request is still
    /// decoding. Idempotent: re-inserting already-published blocks
    /// references nothing new. Returns the number of blocks newly adopted
    /// by the index.
    pub fn publish_prefix(&mut self, id: u64) -> Result<usize> {
        let ti = self
            .table_of(id)
            .ok_or_else(|| anyhow::anyhow!("request {id} holds no KV table to publish"))?;
        let bt = self.cfg.block_tokens;
        let mut adopted = 0usize;
        if let Some(radix) = &mut self.prefix {
            let table = self.tables[ti].as_ref().expect("indexed table present");
            let full = table.tokens.len().min(table.len) / bt;
            if full > 0 {
                let newly = radix.insert(&table.tokens[..full * bt], &table.blocks[..full]);
                adopted = newly.len();
                for b in newly {
                    self.refcount[b] += 1;
                }
            }
        }
        self.tier_gc();
        Ok(adopted)
    }

    /// Reclaim tier entries whose prefix went hot again (publish or
    /// recompute made the tier copy dead) — keeps the tier and the radix
    /// index disjoint at every publish point.
    fn tier_gc(&mut self) {
        let reclaimed = {
            let Some(tier) = &mut self.tier else { return };
            if tier.resident_blocks() == 0 {
                return;
            }
            let mut hot: HashSet<u64> = HashSet::new();
            if let Some(radix) = &self.prefix {
                radix.for_each_key_block(&mut |key, _| {
                    hot.insert(key);
                });
            }
            let before = tier.stats().gc_reclaimed;
            tier.gc(&hot);
            tier.stats().gc_reclaimed - before
        };
        if reclaimed > 0 {
            self.jot(KvEvent::Gc { reclaimed });
        }
    }

    fn decref(&mut self, block: usize) {
        debug_assert!(self.refcount[block] > 0, "refcount underflow on block {block}");
        self.refcount[block] -= 1;
        if self.refcount[block] == 0 {
            self.free.push(block);
        }
    }

    /// Pop a free block, reclaiming LRU prefix-cache blocks if the free
    /// list ran dry — spilling their contents into the tier (when
    /// configured) before the arena slot is reused. The reservation
    /// discipline guarantees success.
    fn alloc_block(&mut self) -> usize {
        if self.free.is_empty() {
            // Collect the eviction set first: `evict_runs` borrows the
            // radix mutably, while spilling needs the whole pool.
            let evicted = match &mut self.prefix {
                Some(radix) => radix.evict_runs(1, &self.refcount),
                None => Vec::new(),
            };
            for (b, run) in evicted {
                // Snapshot contents *now*: under full pressure the freed
                // block may be reused (even as a COW copy target)
                // immediately after this decref.
                self.spill_block(b, &run);
                self.decref(b);
            }
        }
        let b = self
            .free
            .pop()
            .expect("block reservation invariant violated: no free block for a reserved append");
        self.refcount[b] = 1;
        self.blocks_high_water = self.blocks_high_water.max(self.blocks_in_use());
        b
    }

    /// Copy the evicted block `phys` (whose cumulative token run is `run`,
    /// whole blocks ending in `phys`'s own) into the spill tier.
    fn spill_block(&mut self, phys: usize, run: &[usize]) {
        if self.tier.is_none() {
            return;
        }
        let bt = self.cfg.block_tokens;
        debug_assert!(!run.is_empty() && run.len() % bt == 0, "eviction run must be whole blocks");
        let floats = self.n_layers * bt * self.dkv;
        let base = phys * floats;
        let k = self.k[base..base + floats].to_vec();
        let v = self.v[base..base + floats].to_vec();
        let fingerprint = self.block_fingerprint(phys);
        let bytes = self.block_bytes();
        let keys = prefix_block_keys(run, bt);
        let key = *keys.last().expect("non-empty run has a key");
        let parent = keys.len().checked_sub(2).map(|i| keys[i]);
        let tokens = run[run.len() - bt..].to_vec();
        let tier = self.tier.as_mut().expect("checked above");
        tier.spill(key, parent, tokens, k, v, fingerprint, bytes);
        self.jot(KvEvent::Spill { key, bytes });
    }

    /// Extend a prefix lookup's resident hit path with blocks faulted back
    /// from the spill tier: for each missing whole block of the prompt (in
    /// order — a restore chain must be gapless), move the entry out of the
    /// tier into a freshly allocated hot block, re-publish it into the
    /// radix index, and pin it for the caller like any other hit block.
    fn fault_from_tier(&mut self, prompt_tokens: &[usize], blocks: &mut Vec<usize>) {
        if self.tier.is_none() {
            return;
        }
        let bt = self.cfg.block_tokens;
        // Whole blocks strictly inside the prompt: the last prompt position
        // is always recomputed, so a block covering it is useless here.
        let usable = prompt_tokens.len().saturating_sub(1) / bt;
        let keys = prefix_block_keys(&prompt_tokens[..usable * bt], bt);
        while blocks.len() < keys.len() {
            let j = blocks.len();
            let want = &prompt_tokens[j * bt..(j + 1) * bt];
            // Move the entry out before allocating: allocation cannot fail
            // (reservation envelope) and eviction during it cannot touch
            // this key (it is not hot until re-published below).
            let Some((tk, tv, fp)) =
                self.tier.as_mut().expect("checked above").restore(keys[j], want)
            else {
                break;
            };
            let nb = self.alloc_block();
            let floats = self.n_layers * bt * self.dkv;
            let base = nb * floats;
            self.k[base..base + floats].copy_from_slice(&tk);
            self.v[base..base + floats].copy_from_slice(&tv);
            debug_assert_eq!(
                self.block_fingerprint(nb),
                fp,
                "tier restore must reproduce the spilled block bit-identically"
            );
            blocks.push(nb);
            let radix = self.prefix.as_mut().expect("tier requires the prefix index");
            let newly = radix.insert(&prompt_tokens[..(j + 1) * bt], blocks);
            debug_assert_eq!(newly, vec![nb], "restored block re-enters the index");
            // alloc_block's refcount of 1 becomes the index's reference;
            // pin for the caller's table on top, like the resident path.
            self.refcount[nb] += 1;
            self.jot(KvEvent::Restore { key: keys[j], bytes: self.block_bytes() });
        }
    }

    #[inline]
    fn offset(&self, phys: usize, layer: usize, off: usize) -> usize {
        ((phys * self.n_layers + layer) * self.cfg.block_tokens + off) * self.dkv
    }

    fn copy_block(&mut self, src: usize, dst: usize) {
        let floats = self.n_layers * self.cfg.block_tokens * self.dkv;
        self.k.copy_within(src * floats..(src + 1) * floats, dst * floats);
        self.v.copy_within(src * floats..(src + 1) * floats, dst * floats);
    }

    /// Record the token ids written at `start..start + toks.len()` for
    /// `id` — the radix key material. Positions must arrive contiguously
    /// (prefill slices and decode steps both do).
    pub fn note_tokens(&mut self, id: u64, start: usize, toks: &[usize]) -> Result<()> {
        let ti = self
            .table_of(id)
            .ok_or_else(|| anyhow::anyhow!("request {id} holds no KV table (begin missing?)"))?;
        let table = self.tables[ti].as_mut().expect("indexed table present");
        anyhow::ensure!(
            start == table.tokens.len(),
            "request {id}: non-contiguous token record at {start} (have {})",
            table.tokens.len()
        );
        table.tokens.extend_from_slice(toks);
        Ok(())
    }

    /// Write one position's K/V rows through table `ti` — allocating the
    /// tail block on first touch and copy-on-writing a shared block before
    /// mutating it.
    fn append_at(&mut self, ti: usize, layer: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        assert!(pos < self.max_seq, "kv pool overflow at pos {pos}");
        assert_eq!(krow.len(), self.dkv);
        assert_eq!(vrow.len(), self.dkv);
        let bt = self.cfg.block_tokens;
        let (b, off) = (pos / bt, pos % bt);
        let held = self.tables[ti].as_ref().expect("live table").blocks.len();
        assert!(b <= held, "append at pos {pos} skips an unallocated block");
        let phys = if b == held {
            let nb = self.alloc_block();
            self.tables[ti].as_mut().expect("live table").blocks.push(nb);
            nb
        } else {
            let cur = self.tables[ti].as_ref().expect("live table").blocks[b];
            if self.refcount[cur] > 1 {
                // COW: the block is shared (another table and/or the prefix
                // index) — copy before the first divergent write. Drop our
                // reference *before* allocating: if the original is then
                // only index-held it becomes evictable, so the reservation
                // envelope always covers the copy's allocation — under full
                // pressure the evicted original itself is reused as the
                // copy target (contents survive until overwritten; the
                // self-copy is a no-op).
                self.refcount[cur] -= 1;
                debug_assert!(self.refcount[cur] > 0, "shared block lost its other holders");
                let nb = self.alloc_block();
                self.copy_block(cur, nb);
                self.tables[ti].as_mut().expect("live table").blocks[b] = nb;
                self.jot(KvEvent::Cow { block: cur });
                nb
            } else {
                cur
            }
        };
        let i = self.offset(phys, layer, off);
        self.k[i..i + self.dkv].copy_from_slice(krow);
        self.v[i..i + self.dkv].copy_from_slice(vrow);
        let table = self.tables[ti].as_mut().expect("live table");
        table.len = table.len.max(pos + 1);
    }

    #[inline]
    fn k_row(&self, ti: usize, layer: usize, pos: usize, kv_head: usize, d_head: usize) -> &[f32] {
        let table = self.tables[ti].as_ref().expect("live table");
        debug_assert!(pos < table.len, "read past the written prefix");
        let bt = self.cfg.block_tokens;
        let i = self.offset(table.blocks[pos / bt], layer, pos % bt) + kv_head * d_head;
        &self.k[i..i + d_head]
    }

    #[inline]
    fn v_row(&self, ti: usize, layer: usize, pos: usize, kv_head: usize, d_head: usize) -> &[f32] {
        let table = self.tables[ti].as_ref().expect("live table");
        debug_assert!(pos < table.len, "read past the written prefix");
        let bt = self.cfg.block_tokens;
        let i = self.offset(table.blocks[pos / bt], layer, pos % bt) + kv_head * d_head;
        &self.v[i..i + d_head]
    }

    /// A lane-addressed view over `ids` for the transformer's forward
    /// passes — the paged analogue of the old pool's `get_disjoint_mut`.
    /// Rejects unknown and duplicated ids (two lanes over one table would
    /// corrupt the cache).
    pub fn lanes(&mut self, ids: &[u64]) -> Result<PagedLanes<'_>> {
        let mut tables = Vec::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            anyhow::ensure!(
                ids[..i].iter().all(|&prev| prev != id),
                "request {id} appears twice in one KV lane view"
            );
            let ti = self
                .table_of(id)
                .ok_or_else(|| anyhow::anyhow!("request {id} holds no KV table"))?;
            tables.push(ti);
        }
        Ok(PagedLanes { pool: self, tables })
    }

    /// Drop the prefix index's block references and the spill tier's
    /// entries (eviction of the whole cache). With no live requests this
    /// drains pool and tier to empty.
    pub fn clear_prefix_index(&mut self) {
        if let Some(tier) = &mut self.tier {
            tier.clear();
        }
        let blocks = match &mut self.prefix {
            Some(radix) => radix.take_all_blocks(),
            None => return,
        };
        for b in blocks {
            self.decref(b);
        }
    }

    /// Exhaustive refcount/free-list audit for tests: recompute every
    /// block's reference count from live tables plus the prefix index and
    /// compare with the maintained counters.
    pub fn debug_validate(&self) {
        let mut want = vec![0u32; self.cfg.blocks];
        for t in self.tables.iter().flatten() {
            for &b in &t.blocks {
                want[b] += 1;
            }
        }
        if let Some(radix) = &self.prefix {
            radix.for_each_block(&mut |b| want[b] += 1);
        }
        assert_eq!(want, self.refcount, "refcounts diverged from table + index ownership");
        let mut free_sorted = self.free.clone();
        free_sorted.sort_unstable();
        let want_free: Vec<usize> =
            (0..self.cfg.blocks).filter(|&b| self.refcount[b] == 0).collect();
        assert_eq!(free_sorted, want_free, "free list diverged from refcounts");
        let reserved: usize =
            self.tables.iter().flatten().map(|t| t.reserved).sum();
        assert_eq!(reserved, self.reserved_blocks, "reservation accounting diverged");
        if let Some(tier) = &self.tier {
            tier.audit();
            // Tier and hot index stay disjoint: every publish point runs
            // tier GC, so a key is never resident in both tiers at once.
            if let Some(radix) = &self.prefix {
                let mut clash = false;
                radix.for_each_key_block(&mut |key, _| {
                    clash |= tier.contains_key(key);
                });
                assert!(!clash, "spill tier holds a key that is hot in the radix index");
            }
        }
    }

    /// The spill tier's counters (tests/diagnostics) — zeroed default when
    /// no tier is configured.
    pub fn tier_stats(&self) -> TierStats {
        self.tier.as_ref().map(SpillTier::stats).unwrap_or_default()
    }

    /// The spill tier's manifest length (tests) — 0 when no tier.
    pub fn tier_manifest_len(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.manifest().len())
    }
}

/// Lane-addressed mutable view: lane index → request table, all reads and
/// writes translated through block tables (with COW on shared-block
/// writes). This is what [`crate::model::transformer::Transformer`] runs
/// its forward passes against on the paged backend.
pub struct PagedLanes<'a> {
    pool: &'a mut PagedKvPool,
    tables: Vec<usize>,
}

impl KvLanes for PagedLanes<'_> {
    fn lanes(&self) -> usize {
        self.tables.len()
    }

    fn append(&mut self, lane: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.append_at(self.tables[lane], layer, pos, k, v);
    }

    fn k(&self, lane: usize, layer: usize, pos: usize, kv_head: usize, d_head: usize) -> &[f32] {
        self.pool.k_row(self.tables[lane], layer, pos, kv_head, d_head)
    }

    fn v(&self, lane: usize, layer: usize, pos: usize, kv_head: usize, d_head: usize) -> &[f32] {
        self.pool.v_row(self.tables[lane], layer, pos, kv_head, d_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;

    fn pool(blocks: usize, bt: usize, prefix: bool) -> PagedKvPool {
        let cfg = ModelConfig::tiny();
        PagedKvPool::new(&cfg, 64, KvPoolConfig::paged(blocks, bt, prefix))
    }

    fn dkv() -> usize {
        ModelConfig::tiny().d_kv()
    }

    fn fill(p: &mut PagedKvPool, id: u64, positions: std::ops::Range<usize>, tag: f32) {
        let ti = p.table_of(id).unwrap();
        let n_layers = p.n_layers;
        for pos in positions {
            for layer in 0..n_layers {
                let row = vec![tag + pos as f32; dkv()];
                p.append_at(ti, layer, pos, &row, &row);
            }
        }
    }

    #[test]
    fn begin_append_read_release_lifecycle() {
        let mut p = pool(4, 8, false);
        assert_eq!(p.begin(1, &[], 20).unwrap(), 0, "no prefix cache, no hit");
        assert_eq!(p.reserved_blocks(), 3, "20 tokens over 8-token blocks");
        assert_eq!(p.blocks_in_use(), 0, "blocks allocate lazily on append");
        fill(&mut p, 1, 0..9, 0.0);
        assert_eq!(p.blocks_in_use(), 2, "positions 0..9 span two blocks");
        assert_eq!(p.request_len(1), Some(9));
        let ti = p.table_of(1).unwrap();
        let dh = ModelConfig::tiny().d_head();
        assert_eq!(p.k_row(ti, 0, 8, 0, dh), &vec![8.0; dh][..]);
        assert_eq!(p.v_row(ti, 1, 3, 0, dh), &vec![3.0; dh][..]);
        p.debug_validate();
        assert!(p.release(1));
        assert!(!p.release(1), "double release is a no-op");
        assert_eq!(p.blocks_in_use(), 0, "pool drains after release");
        assert_eq!(p.reserved_blocks(), 0);
        assert_eq!(p.blocks_high_water(), 2);
        p.debug_validate();
    }

    #[test]
    fn reservation_gates_admission_and_release_recovers() {
        let mut p = pool(4, 8, false);
        p.begin(1, &[], 32).unwrap(); // 4 blocks: the whole pool
        assert!(p.begin(2, &[], 1).is_err(), "over-budget begin must refuse");
        assert!(p.release(1));
        p.begin(2, &[], 1).unwrap();
        assert_eq!(p.reserved_blocks(), 1);
    }

    #[test]
    fn rebegin_clears_the_table() {
        let mut p = pool(4, 8, false);
        p.begin(7, &[], 8).unwrap();
        fill(&mut p, 7, 0..5, 1.0);
        assert_eq!(p.request_len(7), Some(5));
        // Same id re-begins: fresh empty table, blocks returned.
        p.begin(7, &[], 8).unwrap();
        assert_eq!(p.request_len(7), Some(0));
        assert_eq!(p.blocks_in_use(), 0);
        p.debug_validate();
    }

    #[test]
    fn resume_requires_a_live_table() {
        let mut p = pool(2, 8, false);
        assert!(p.resume(5).is_err(), "never-admitted id cannot resume");
        p.begin(5, &[], 4).unwrap();
        assert!(p.resume(5).is_ok());
        fill(&mut p, 5, 0..3, 2.0);
        assert!(p.resume(5).is_ok(), "resume keeps contents intact");
        assert_eq!(p.request_len(5), Some(3));
        p.release(5);
        assert!(p.resume(5).is_err(), "released id cannot resume");
    }

    #[test]
    fn resident_bytes_track_allocation_not_capacity() {
        let mut p = pool(8, 8, false);
        assert_eq!(p.bytes(), 0, "no allocated blocks, no resident bytes");
        p.begin(1, &[], 20).unwrap();
        assert_eq!(p.bytes(), 0, "reservation alone allocates nothing");
        fill(&mut p, 1, 0..9, 0.0);
        let per_block = p.capacity_bytes() / 8;
        assert_eq!(p.bytes(), 2 * per_block);
        assert!(p.capacity_bytes() > p.bytes());
    }

    #[test]
    fn prefix_hit_reuses_blocks_and_caps_before_the_last_token() {
        let mut p = pool(8, 4, true);
        let prompt: Vec<usize> = (0..12).collect();
        // Publisher computes everything, then releases (publish-on-finish).
        assert_eq!(p.begin(1, &prompt, 16).unwrap(), 0, "cold cache");
        fill(&mut p, 1, 0..12, 3.0);
        p.note_tokens(1, 0, &prompt).unwrap();
        let publisher_blocks = p.request_blocks(1).unwrap();
        p.release(1);
        assert_eq!(p.blocks_in_use(), 3, "published blocks survive in the index");
        p.debug_validate();

        // Identical prompt: hit capped at prompt - 1 = 11 (the last prompt
        // position must be recomputed for its logits).
        let hit = p.begin(2, &prompt, 16).unwrap();
        assert_eq!(hit, 11);
        assert_eq!(p.cached_of(2), Some(11));
        let shared = p.request_blocks(2).unwrap();
        assert_eq!(shared, publisher_blocks, "the hit binds the published blocks");
        assert_eq!(p.request_len(2), Some(11));
        // Shorter shared prefix: 8-token prompt hits 2 full blocks (cap 7
        // rounds the hit *down* into the shared prefix, keeping 2 blocks).
        let hit = p.begin(3, &prompt[..8], 12).unwrap();
        assert_eq!(hit, 7);
        assert_eq!(p.request_blocks(3).unwrap(), publisher_blocks[..2].to_vec());
        p.debug_validate();
        let stats = p.stats();
        assert_eq!(stats.prefix_lookups, 3);
        assert_eq!(stats.prefix_hits, 2);
        assert_eq!(stats.prefix_hit_tokens, 18);
    }

    #[test]
    fn cow_write_never_mutates_the_shared_block() {
        let mut p = pool(8, 4, true);
        let prompt: Vec<usize> = (100..108).collect();
        p.begin(1, &prompt, 8).unwrap();
        fill(&mut p, 1, 0..8, 4.0);
        p.note_tokens(1, 0, &prompt).unwrap();
        p.release(1);

        // Hit = 7 (cap): block 1 is shared with the index and position 7
        // lands inside it — the first write must COW, not mutate.
        let hit = p.begin(2, &prompt, 8).unwrap();
        assert_eq!(hit, 7);
        let before = p.request_blocks(2).unwrap();
        let shared_phys = before[1];
        let fp = p.block_fingerprint(shared_phys);
        fill(&mut p, 2, 7..8, 9.0); // divergent write at pos 7
        let after = p.request_blocks(2).unwrap();
        assert_ne!(after[1], shared_phys, "the write must land in a private copy");
        assert_eq!(after[0], before[0], "the untouched shared block stays bound");
        assert_eq!(
            p.block_fingerprint(shared_phys),
            fp,
            "COW must leave the shared block byte-identical"
        );
        // The copy carried the shared prefix of the block (positions 4..7).
        let ti = p.table_of(2).unwrap();
        let dh = ModelConfig::tiny().d_head();
        assert_eq!(p.k_row(ti, 0, 5, 0, dh), &vec![4.0 + 5.0; dh][..]);
        assert_eq!(p.k_row(ti, 0, 7, 0, dh), &vec![9.0 + 7.0; dh][..]);
        p.debug_validate();
    }

    #[test]
    fn cow_under_full_pressure_reuses_the_evicted_original() {
        // 4 blocks × 4 tokens, prefix cache on: a publisher leaves 2
        // blocks in the index; reader B binds them (hit 7, reservation 2);
        // request C takes the last 2 free blocks. B's capped-position
        // write must COW with an *empty free list* — dropping B's
        // reference first makes the index-held original evictable, so the
        // copy lands (in the original itself) instead of panicking on the
        // reservation invariant.
        let mut p = pool(4, 4, true);
        let prompt: Vec<usize> = (0..8).collect();
        p.begin(1, &prompt, 8).unwrap();
        fill(&mut p, 1, 0..8, 1.0);
        p.note_tokens(1, 0, &prompt).unwrap();
        p.release(1);
        assert_eq!(p.blocks_in_use(), 2, "published blocks resident");

        let hit = p.begin(2, &prompt, 8).unwrap();
        assert_eq!(hit, 7);
        p.begin(3, &[], 8).unwrap();
        fill(&mut p, 3, 0..8, 2.0);
        assert_eq!(p.blocks_in_use(), 4, "free list drained");
        // The COW write under full pressure.
        fill(&mut p, 2, 7..8, 9.0);
        let dh = ModelConfig::tiny().d_head();
        let ti = p.table_of(2).unwrap();
        assert_eq!(p.k_row(ti, 0, 5, 0, dh), &vec![1.0 + 5.0; dh][..], "prefix survives");
        assert_eq!(p.k_row(ti, 0, 7, 0, dh), &vec![9.0 + 7.0; dh][..]);
        p.debug_validate();
        p.release(2);
        p.release(3);
        p.clear_prefix_index();
        assert_eq!(p.blocks_in_use(), 0);
        p.debug_validate();
    }

    #[test]
    fn lru_eviction_reclaims_index_blocks_under_pressure() {
        let mut p = pool(4, 4, true);
        let a: Vec<usize> = (0..8).collect();
        p.begin(1, &a, 8).unwrap();
        fill(&mut p, 1, 0..8, 5.0);
        p.note_tokens(1, 0, &a).unwrap();
        p.release(1);
        assert_eq!(p.blocks_in_use(), 2, "two published blocks resident");
        // A fresh 16-token request needs all 4 blocks: the index's LRU
        // blocks must be reclaimed on demand.
        let b: Vec<usize> = (50..66).collect();
        assert_eq!(p.begin(2, &b, 16).unwrap(), 0, "different prompt, no hit");
        fill(&mut p, 2, 0..16, 6.0);
        assert_eq!(p.blocks_in_use(), 4);
        p.debug_validate();
        p.release(2);
        p.clear_prefix_index();
        assert_eq!(p.blocks_in_use(), 0, "pool drains once the index is cleared");
        p.debug_validate();
    }

    #[test]
    fn lanes_reject_duplicates_and_unknown_ids() {
        let mut p = pool(4, 8, false);
        p.begin(1, &[], 8).unwrap();
        assert!(p.lanes(&[1, 1]).is_err(), "duplicate lanes over one table");
        assert!(p.lanes(&[2]).is_err(), "unknown id");
        assert!(p.lanes(&[1]).is_ok());
    }

    fn tier_pool(blocks: usize, bt: usize, tier: usize) -> PagedKvPool {
        let cfg = ModelConfig::tiny();
        PagedKvPool::new(&cfg, 64, KvPoolConfig::paged(blocks, bt, true).with_tier(tier))
    }

    #[test]
    fn eviction_spills_and_lookup_faults_back_bit_identical() {
        let mut p = tier_pool(4, 4, 4);
        let a: Vec<usize> = (0..8).collect();
        p.begin(1, &a, 8).unwrap();
        fill(&mut p, 1, 0..8, 5.0);
        p.note_tokens(1, 0, &a).unwrap();
        p.release(1);
        let published = {
            // Recover the published physical blocks through a fresh hit.
            let hit = p.begin(9, &a, 8).unwrap();
            assert_eq!(hit, 7);
            let blocks = p.request_blocks(9).unwrap();
            p.release(9);
            blocks
        };
        let fp_a0 = p.block_fingerprint(published[0]);

        // A 16-token stranger needs the whole arena: both published blocks
        // are evicted — and with a tier, spilled instead of dropped.
        let b: Vec<usize> = (50..66).collect();
        assert_eq!(p.begin(2, &b, 16).unwrap(), 0);
        fill(&mut p, 2, 0..16, 6.0);
        assert_eq!(p.tier_stats().spills, 2, "both evicted blocks spilled");
        assert_eq!(p.tier_stats().resident_blocks, 2);
        p.debug_validate();
        p.note_tokens(2, 0, &b).unwrap();
        p.release(2);

        // Re-begin the first prompt: the radix misses (evicted) but the
        // tier faults the first block back, bit-identical.
        let hit = p.begin(3, &a, 8).unwrap();
        assert_eq!(hit, 4, "one whole block restored from the tier");
        let stats = p.tier_stats();
        assert_eq!(stats.restores, 1);
        assert!(stats.restored_bytes > 0);
        let restored = p.request_blocks(3).unwrap();
        assert_eq!(
            p.block_fingerprint(restored[0]),
            fp_a0,
            "restored block must be bit-identical to the spilled original"
        );
        p.debug_validate();
        p.release(3);
        p.clear_prefix_index();
        assert_eq!(p.blocks_in_use(), 0, "pool drains");
        assert_eq!(p.tier_stats().resident_blocks, 0, "tier drains");
        p.debug_validate();
    }

    #[test]
    fn publish_prefix_shares_blocks_mid_flight() {
        let mut p = pool(8, 4, true);
        let prompt: Vec<usize> = (0..8).collect();
        p.begin(1, &prompt, 12).unwrap();
        fill(&mut p, 1, 0..8, 3.0);
        p.note_tokens(1, 0, &prompt).unwrap();
        // Publish at prefill-complete: request 1 still live and decoding.
        assert_eq!(p.publish_prefix(1).unwrap(), 2, "two whole blocks adopted");
        assert_eq!(p.publish_prefix(1).unwrap(), 0, "re-publish is idempotent");
        p.debug_validate();

        // A fork of the same prompt hits the published blocks while the
        // publisher is still running.
        let hit = p.begin(2, &prompt, 12).unwrap();
        assert_eq!(hit, 7);
        let publisher = p.request_blocks(1).unwrap();
        assert_eq!(p.request_blocks(2).unwrap(), publisher, "fork binds the publisher's blocks");
        let fp = p.block_fingerprint(publisher[1]);
        fill(&mut p, 2, 7..8, 9.0); // divergent write → COW
        assert_ne!(p.request_blocks(2).unwrap()[1], publisher[1]);
        assert_eq!(p.block_fingerprint(publisher[1]), fp, "publisher's block untouched");
        assert_eq!(p.request_blocks(1).unwrap(), publisher, "publisher table unchanged");
        p.debug_validate();
        p.release(1);
        p.release(2);
        p.clear_prefix_index();
        assert_eq!(p.blocks_in_use(), 0);
        p.debug_validate();
    }

    #[test]
    fn tier_gc_reclaims_republished_prefixes() {
        let mut p = tier_pool(4, 4, 8);
        let a: Vec<usize> = (0..8).collect();
        p.begin(1, &a, 8).unwrap();
        fill(&mut p, 1, 0..8, 1.0);
        p.note_tokens(1, 0, &a).unwrap();
        p.release(1);
        // Evict both published blocks into the tier.
        let b: Vec<usize> = (50..66).collect();
        p.begin(2, &b, 16).unwrap();
        fill(&mut p, 2, 0..16, 2.0);
        assert_eq!(p.tier_stats().resident_blocks, 2);
        p.release(2);
        // Re-begin the first prompt: block 0 faults back; block 1 stays in
        // the tier (the hit is capped below the last prompt token). The
        // release then republishes both blocks — the tier's copy of block 1
        // is now dead and GC reclaims it.
        let hit = p.begin(3, &a, 8).unwrap();
        assert_eq!(hit, 4, "first block restored from the tier");
        assert_eq!(p.tier_stats().restores, 1);
        fill(&mut p, 3, hit..8, 1.0);
        p.note_tokens(3, hit, &a[hit..]).unwrap();
        p.release(3);
        assert_eq!(p.tier_stats().gc_reclaimed, 1, "republished block leaves the tier via GC");
        p.debug_validate();
    }

    #[test]
    fn churn_keeps_accounting_exact() {
        // Randomized begin/append/release churn with the audit run at every
        // step — the paged analogue of the old slot pool's churn test.
        let cfg = ModelConfig::tiny();
        let mut p = PagedKvPool::new(&cfg, 64, KvPoolConfig::paged(6, 8, true));
        let mut rng = Rng::new(0xC0DE);
        let mut held: Vec<(u64, usize)> = Vec::new(); // (id, written)
        for step in 0..400u64 {
            if !held.is_empty() && rng.below(2) == 0 {
                let (id, written) = held.remove(rng.below(held.len()));
                let toks: Vec<usize> = (0..written).map(|i| (id as usize * 7 + i) % 97).collect();
                if written > 0 {
                    p.note_tokens(id, 0, &toks).unwrap();
                }
                assert!(p.release(id), "step {step}: release of held id {id}");
            } else {
                let id = 1000 + step;
                let want = 1 + rng.below(24);
                match p.begin(id, &[], want) {
                    Ok(_) => {
                        let written = rng.below(want + 1);
                        fill(&mut p, id, 0..written, step as f32);
                        held.push((id, written));
                    }
                    Err(_) => {
                        // Over budget is legal under churn; accounting must
                        // still hold.
                    }
                }
            }
            assert_eq!(p.requests_in_use(), held.len(), "step {step}");
            p.debug_validate();
        }
        for (id, _) in held {
            p.release(id);
        }
        p.clear_prefix_index();
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.reserved_blocks(), 0);
        p.debug_validate();
    }
}
