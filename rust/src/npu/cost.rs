//! Kernel cost accounting: the latency-breakdown structure every simulated
//! kernel reports, in the MEM / DQ / CMP decomposition of Fig. 5.
//!
//! Kernels compute per-stage times from the hardware model (`config`,
//! `memory`, `hvx`, `hmx`); this module combines them under sequential or
//! overlapped execution and keeps the op counters used for roofline
//! analysis in EXPERIMENTS.md §Perf.

/// Latency breakdown of one kernel invocation, µs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    /// Memory loading (weights from DDR, whatever the path).
    pub mem_us: f64,
    /// Dequantization / table precomputation on the vector cores.
    pub dq_us: f64,
    /// Computation (matrix core GEMM or vector-core lookups + reduction).
    pub cmp_us: f64,
    /// Fixed overhead not in any of the three (kernel launch, NPU↔CPU sync).
    pub overhead_us: f64,
}

impl Breakdown {
    /// Total when the stages run back to back (non-pipelined kernels, and
    /// the "Sequential" arm of Fig. 17).
    pub fn sequential_us(&self) -> f64 {
        self.mem_us + self.dq_us + self.cmp_us + self.overhead_us
    }

    /// Total under perfect three-stage software pipelining (Fig. 9): the
    /// steady state is dominated by the slowest stage; the other stages
    /// contribute one tile of fill/drain each, approximated by `fill_us`.
    pub fn pipelined_us(&self, fill_us: f64) -> f64 {
        self.mem_us.max(self.dq_us).max(self.cmp_us) + fill_us + self.overhead_us
    }

    /// Three-stage pipeline totals over `tiles` identical tiles of this
    /// per-tile breakdown: `(steady, fill)` where steady state is the
    /// slowest stage times the tile count (Fig. 9) and fill/drain is one
    /// pass of the two non-dominant stages. Every prefill-GEMM cost
    /// consumer — the kernel itself and the plan cost surface — derives
    /// its pipelined total from this one formula.
    pub fn pipeline_steady_fill(&self, tiles: f64) -> (f64, f64) {
        let slowest = self.mem_us.max(self.dq_us).max(self.cmp_us);
        let steady = slowest * tiles;
        let fill = self.mem_us + self.dq_us + self.cmp_us - slowest;
        (steady, fill)
    }

    pub fn scaled(&self, f: f64) -> Breakdown {
        Breakdown {
            mem_us: self.mem_us * f,
            dq_us: self.dq_us * f,
            cmp_us: self.cmp_us * f,
            overhead_us: self.overhead_us * f,
        }
    }

    pub fn add(&self, other: &Breakdown) -> Breakdown {
        Breakdown {
            mem_us: self.mem_us + other.mem_us,
            dq_us: self.dq_us + other.dq_us,
            cmp_us: self.cmp_us + other.cmp_us,
            overhead_us: self.overhead_us + other.overhead_us,
        }
    }
}

/// Operation counters a kernel accumulates while executing functionally —
/// the bridge between the bit-exact implementation and the cost model, and
/// the input to the roofline estimates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// VLUT instructions issued.
    pub vlut_instrs: usize,
    /// Plain vector-ALU instructions (adds, shifts, packs).
    pub valu_instrs: usize,
    /// Matrix-core MACs.
    pub hmx_macs: usize,
    /// Scalar float operations (the slow path LUT dequant avoids).
    pub scalar_float_ops: usize,
    /// Int→float conversion ops (ConvertDQ baseline).
    pub convert_ops: usize,
    /// Bytes moved DDR→on-chip.
    pub ddr_bytes: usize,
    /// Bytes spilled to / reloaded from L2 (what the TCM spill buffer kills).
    pub l2_spill_bytes: usize,
    /// Bytes staged through the TCM spill buffer.
    pub tcm_spill_bytes: usize,
}

impl OpCounts {
    pub fn add(&mut self, o: &OpCounts) {
        self.vlut_instrs += o.vlut_instrs;
        self.valu_instrs += o.valu_instrs;
        self.hmx_macs += o.hmx_macs;
        self.scalar_float_ops += o.scalar_float_ops;
        self.convert_ops += o.convert_ops;
        self.ddr_bytes += o.ddr_bytes;
        self.l2_spill_bytes += o.l2_spill_bytes;
        self.tcm_spill_bytes += o.tcm_spill_bytes;
    }
}

/// A kernel's simulated result: latency breakdown + counters + where it ran.
#[derive(Debug, Clone)]
pub struct KernelCost {
    pub breakdown: Breakdown,
    pub ops: OpCounts,
    /// Human-readable kernel id for reports.
    pub label: String,
}

impl KernelCost {
    pub fn total_us(&self) -> f64 {
        self.breakdown.sequential_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_sums_pipelined_maxes() {
        let b = Breakdown { mem_us: 10.0, dq_us: 4.0, cmp_us: 8.0, overhead_us: 1.0 };
        assert_eq!(b.sequential_us(), 23.0);
        // Steady state = max stage (10) + fill + overhead.
        assert_eq!(b.pipelined_us(2.0), 13.0);
        // Pipelining can never be slower than sequential for zero fill.
        assert!(b.pipelined_us(0.0) <= b.sequential_us());
    }

    #[test]
    fn scale_and_add() {
        let b = Breakdown { mem_us: 1.0, dq_us: 2.0, cmp_us: 3.0, overhead_us: 0.5 };
        let s = b.scaled(2.0);
        assert_eq!(s.dq_us, 4.0);
        let sum = b.add(&s);
        assert_eq!(sum.cmp_us, 9.0);
        assert_eq!(sum.sequential_us(), 3.0 * b.sequential_us());
    }

    #[test]
    fn opcounts_accumulate() {
        let mut a = OpCounts { vlut_instrs: 1, ddr_bytes: 100, ..Default::default() };
        let b = OpCounts { vlut_instrs: 2, hmx_macs: 50, ..Default::default() };
        a.add(&b);
        assert_eq!(a.vlut_instrs, 3);
        assert_eq!(a.hmx_macs, 50);
        assert_eq!(a.ddr_bytes, 100);
    }
}
