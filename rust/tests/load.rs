//! Load-harness integration properties: every arrival process × overload
//! policy combination must keep the serving loop's terminal-state
//! accounting exact, its KV pool clean, and (with shedding on) its
//! admitted deadlines unmissable — under the preemption churn a
//! contended tiny engine produces.

use tman::coordinator::engine::Engine;
use tman::coordinator::server::{OverloadPolicy, ServeOpts, Server, TraceProfile};
use tman::kvpool::KvPoolConfig;
use tman::load::{ArrivalProcess, LoadSpec};
use tman::model::config::ModelConfig;
use tman::model::weights::random_transformer;
use tman::npu::config::SocConfig;

const MODEL_SEED: u64 = 1;

/// A deliberately contended engine: small chunk (long prompts preempt and
/// resume across many slices) and few KV slots (decode lanes evict).
fn contended_engine() -> Engine {
    let model = random_transformer(&ModelConfig::tiny(), MODEL_SEED);
    Engine::reference(model, SocConfig::oneplus12(), 16, 4, 3).expect("engine")
}

fn prefix_engine() -> Engine {
    let model = random_transformer(&ModelConfig::tiny(), MODEL_SEED);
    let blocks = 3 * model.cfg.max_seq.div_ceil(16);
    let kv = KvPoolConfig::paged(blocks, 16, true);
    Engine::reference_paged(model, SocConfig::oneplus12(), 16, 4, kv).expect("engine")
}

fn serve(
    spec: &LoadSpec,
    n: usize,
    seed: u64,
    policy: OverloadPolicy,
    engine: Engine,
) -> tman::coordinator::metrics::FleetMetrics {
    let opts = ServeOpts { max_batch: 2, policy, ..Default::default() };
    let mut server = Server::new(engine, opts);
    let fleet = server.run(&spec.trace(n, seed)).expect("serve");
    assert_eq!(
        server.engine().kv_slots_in_use(),
        0,
        "every terminal path must release its KV"
    );
    fleet
}

fn all_processes() -> Vec<ArrivalProcess> {
    vec![
        ArrivalProcess::Poisson { mean_gap_us: 300.0 },
        ArrivalProcess::bursty(300.0),
        ArrivalProcess::diurnal(300.0),
        ArrivalProcess::flash_crowd(300.0),
    ]
}

#[test]
fn accounting_invariant_holds_under_randomized_overload() {
    // Every submitted request must end in exactly one terminal state —
    // completed, shed, or rejected — for every arrival shape and policy,
    // across seeds. The serving loop also cross-checks this after every
    // work item; this test pins the external contract.
    let n = 16;
    let policy = OverloadPolicy { queue_cap: Some(2), class_caps: vec![], shed: true };
    for process in all_processes() {
        for seed in [1u64, 2] {
            let spec = LoadSpec::new(process.clone(), TraceProfile::tiny()).with_slo(1_500.0);
            let fleet = serve(&spec, n, seed, policy.clone(), contended_engine());
            assert_eq!(fleet.submitted, n, "open-loop load submits every request");
            assert_eq!(
                fleet.completions.len() + fleet.shed + fleet.rejected,
                fleet.submitted,
                "{process:?} seed {seed}: terminal states must partition submissions"
            );
            assert_eq!(fleet.admitted(), fleet.completions.len());
            let by_class: usize = fleet.shed_by_priority.iter().map(|&(_, c)| c).sum();
            assert_eq!(by_class, fleet.shed, "per-class shed counts must sum to the total");
            assert_eq!(
                fleet.deadline_misses(),
                0,
                "{process:?} seed {seed}: shedding makes admitted deadlines unmissable"
            );
        }
    }
}

#[test]
fn each_policy_knob_alone_keeps_the_books() {
    let spec = LoadSpec::new(ArrivalProcess::flash_crowd(300.0), TraceProfile::tiny())
        .with_slo(1_000.0);
    // Queue bound only: displacement and rejection, no deadline shedding.
    let capped = serve(
        &spec,
        16,
        3,
        OverloadPolicy { queue_cap: Some(1), class_caps: vec![], shed: false },
        contended_engine(),
    );
    assert_eq!(capped.completions.len() + capped.shed + capped.rejected, capped.submitted);
    assert!(
        capped.shed + capped.rejected > 0,
        "a flash crowd against a 1-deep queue must drop work"
    );
    // Shedding only: unbounded queue, deadline enforcement.
    let shed = serve(
        &spec,
        16,
        3,
        OverloadPolicy { queue_cap: None, class_caps: vec![], shed: true },
        contended_engine(),
    );
    assert_eq!(shed.completions.len() + shed.shed + shed.rejected, shed.submitted);
    assert_eq!(shed.deadline_misses(), 0);
}

#[test]
fn per_class_queue_caps_reject_only_the_capped_class() {
    // A class cap bounds one priority's unstarted queue depth without
    // touching the others, and every class-cap rejection lands in the
    // per-class rejection ledger.
    let spec = LoadSpec::new(ArrivalProcess::flash_crowd(250.0), TraceProfile::tiny());
    let uncapped = serve(&spec, 24, 4, OverloadPolicy::default(), contended_engine());
    assert_eq!(uncapped.rejected, 0, "no caps: nothing is rejected");
    // Cap the batch class (priority 4, the long-document requests) at one
    // queued request; leave the interactive class unbounded.
    let policy =
        OverloadPolicy { queue_cap: None, class_caps: vec![(4, 1)], shed: false };
    let capped = serve(&spec, 24, 4, policy, contended_engine());
    assert_eq!(
        capped.completions.len() + capped.shed + capped.rejected,
        capped.submitted,
        "terminal accounting survives class caps"
    );
    assert!(capped.rejected > 0, "a flash crowd must overflow a 1-deep class queue");
    let ledger: usize = capped.rejected_by_priority.iter().map(|&(_, c)| c).sum();
    assert_eq!(ledger, capped.rejected, "per-class rejections must sum to the total");
    for &(p, c) in &capped.rejected_by_priority {
        assert_eq!(p, 4, "only the capped class may be rejected, saw p{p} x{c}");
    }
}

#[test]
fn serving_a_load_spec_is_deterministic_end_to_end() {
    let spec = LoadSpec::new(ArrivalProcess::bursty(300.0), TraceProfile::tiny())
        .with_slo(2_000.0)
        .with_fanout(2);
    let policy = OverloadPolicy { queue_cap: Some(3), class_caps: vec![], shed: true };
    let a = serve(&spec, 12, 9, policy.clone(), contended_engine());
    let b = serve(&spec, 12, 9, policy, contended_engine());
    assert_eq!(a.report(), b.report(), "same spec + seed must replay exactly");
    assert_eq!(a.ttft_us(), b.ttft_us());
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.rejected, b.rejected);
}

#[test]
fn fanout_siblings_hit_the_prefix_cache() {
    // TTC-style fan-out shares the whole prompt across siblings, so a
    // prefix-cache engine must convert the duplicates into cache hits.
    let spec = LoadSpec::new(
        ArrivalProcess::Poisson { mean_gap_us: 800.0 },
        TraceProfile::tiny().with_shared_prefix(48),
    )
    .with_fanout(4);
    let fleet = serve(&spec, 16, 6, OverloadPolicy::default(), prefix_engine());
    assert_eq!(fleet.completions.len(), 16, "no policy active: everything completes");
    assert!(
        fleet.prefix_hits > 0,
        "sibling prompts must hit the prefix cache ({} lookups)",
        fleet.prefix_lookups
    );
}
