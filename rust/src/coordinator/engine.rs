//! The T-MAN inference engine: the Layer-3 coordinator that drives the two
//! execution paths of the unified weight layout — chunked prefill through
//! the matrix path, token-by-token decoding through the LUT vector path.
//!
//! Numerics come from a pluggable [`Backend`] (pure-Rust reference
//! transformer by default; PJRT-executed artifacts behind the `pjrt`
//! feature). On-device latency/energy always come from the NPU simulator
//! applied to the model's projection shapes (DESIGN.md §1 explains the
//! substitution), so the performance model is backend-independent.
//!
//! Two entry levels:
//! - [`Engine::generate`] serves one request end to end (the original
//!   single-shot path).
//! - [`Engine::begin_request`] / [`Engine::resume_request`] /
//!   [`Engine::prefill_slice`] / [`Engine::decode_token`] /
//!   [`Engine::decode_batch`] / [`Engine::end_request`] expose the same
//!   machinery one scheduler work-item at a time, addressed by request id —
//!   this is what the multi-request serving loop in
//!   [`crate::coordinator::server`] drives. `decode_batch` advances every
//!   batched request through one *shared-weight-pass* forward (the batched
//!   table-lookup kernel: the bit-serial weight stream is read once and
//!   applied to all requests' activation tables) and prices it with the
//!   kernel's own batched cost model — table-lookup GEMV is weight-traffic
//!   bound, so one pass over the quantized weights serves every request.

use crate::coordinator::metrics::{sim_energy_j, PhaseTimer, RequestMetrics};
use crate::kernels::dequant_gemm::tman_gemm_latency_us;
use crate::kernels::lut_gemv::{
    tman_gemv_batched_latency_curve, tman_gemv_batched_latency_us, tman_gemv_latency_us,
};
use crate::model::sampler;
use crate::model::tokenizer;
use crate::model::transformer::Transformer;
use crate::npu::config::SocConfig;
use crate::npu::energy::Placement;
use crate::npu::memory::LoadMethod;
use crate::quant::formats::{ActDtype, Granularity, QuantFormat, WeightDtype};
use crate::runtime::backend::{Backend, DecodeStep, ModelShape, ReferenceBackend};
use crate::util::Rng;
use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::runtime::executor::NpuModelRuntime;
#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::path::Path;

/// Decoding configuration for one request.
#[derive(Debug, Clone)]
pub struct GenerateOpts {
    pub max_new_tokens: usize,
    /// 0.0 => greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Stop generation at this byte (e.g. b'\n' ends a line). None = run to
    /// max_new_tokens. The stop byte itself is never emitted.
    pub stop_byte: Option<u8>,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        Self { max_new_tokens: 64, temperature: 0.8, top_k: 40, seed: 0, stop_byte: None }
    }
}

/// Request id [`Engine::generate`] binds internally for its single request.
const GENERATE_REQ_ID: u64 = u64::MAX;

fn quant_format(bits: u32, block: usize) -> QuantFormat {
    QuantFormat::new(
        if bits == 2 { WeightDtype::Int2 } else { WeightDtype::Int4 },
        ActDtype::Fp16,
        Granularity::PerBlock(block),
    )
}

/// The serving engine.
pub struct Engine {
    backend: Backend,
    pub soc: SocConfig,
    pub fmt: QuantFormat,
    shape: ModelShape,
    /// Simulated µs of the projection kernels for one decode batch of
    /// width `b` (`decode_proj_batch_us[b - 1]`), derived from the batched
    /// LUT-GEMV cost model (shared weight DMA + per-lane VLUT issue),
    /// precomputed up to the backend's KV-slot capacity. Entry 0 is the
    /// solo decode cost.
    decode_proj_batch_us: Vec<f64>,
    /// Simulated µs per prefill chunk (projection kernels).
    sim_prefill_chunk_us: f64,
}

impl Engine {
    /// Load AOT artifacts and prepare the simulator against `soc`.
    #[cfg(feature = "pjrt")]
    pub fn load(artifacts: &Path, soc: SocConfig) -> Result<Self> {
        let runtime = NpuModelRuntime::load(artifacts)
            .with_context(|| format!("loading artifacts from {}", artifacts.display()))?;
        let shape = ModelShape::from_meta(&runtime.meta);
        Ok(Self::assemble(Backend::Pjrt(runtime), soc, shape))
    }

    /// Build an engine over the pure-Rust reference backend: `model` runs
    /// the numerics, the NPU simulator provides on-device latency/energy
    /// for a W_INT`bits` per-block deployment with `chunk`-token prefill
    /// slices and `kv_slots` per-request KV-cache slots.
    pub fn reference(
        model: Transformer,
        soc: SocConfig,
        chunk: usize,
        bits: u32,
        kv_slots: usize,
    ) -> Result<Self> {
        anyhow::ensure!(chunk > 0, "prefill chunk must be positive");
        anyhow::ensure!(kv_slots > 0, "need at least one KV slot");
        anyhow::ensure!(bits == 2 || bits == 4, "bits must be 2 or 4, got {bits}");
        let shape = ModelShape::from_config(&model.cfg, chunk, bits, 64);
        let backend = Backend::Reference(ReferenceBackend::new(model, kv_slots));
        Ok(Self::assemble(backend, soc, shape))
    }

    fn assemble(backend: Backend, soc: SocConfig, shape: ModelShape) -> Self {
        let fmt = quant_format(shape.bits, shape.block);
        let npu = &soc.npu;
        let chunk = shape.chunk.max(1);
        // Decode projections priced by the batched LUT-GEMV kernel for
        // every batch width a KV slot could back (entry 0 = solo decode).
        // The lm head runs once per token like any other projection.
        let max_batch = backend.kv_slot_capacity().max(1);
        let mut dec_batch = vec![0.0f64; max_batch];
        let mut gemv_shapes = shape.proj_shapes();
        gemv_shapes.push((shape.vocab, shape.d_model));
        for &(m, k) in &gemv_shapes {
            // One tiling search per shape covers every batch width.
            let curve = tman_gemv_batched_latency_curve(npu, m, k, fmt, max_batch);
            for (acc, us) in dec_batch.iter_mut().zip(curve) {
                *acc += us;
            }
        }
        let mut pre = 0.0;
        for (m, k) in shape.proj_shapes() {
            pre += tman_gemm_latency_us(npu, chunk, m, k, fmt);
        }
        pre += tman_gemv_latency_us(npu, shape.vocab, shape.d_model, fmt);
        Self {
            backend,
            soc,
            fmt,
            shape,
            decode_proj_batch_us: dec_batch,
            sim_prefill_chunk_us: pre,
        }
    }

    pub fn shape(&self) -> &ModelShape {
        &self.shape
    }

    /// Prefill chunk length (0 = artifacts without a prefill executable).
    pub fn chunk(&self) -> usize {
        self.shape.chunk
    }

    pub fn max_seq(&self) -> usize {
        self.shape.seq
    }

    /// DMA time to stream one request's KV cache at context length `ctx`.
    fn kv_transfer_us(&self, ctx: usize) -> f64 {
        let kv_bytes = 2 * self.shape.n_layers * ctx * self.shape.d_kv() * 2;
        LoadMethod::Dma.transfer_us(&self.soc.npu, kv_bytes, 1)
    }

    /// Simulated on-device time for one decode step at context length `ctx`.
    pub fn sim_decode_us(&self, ctx: usize) -> f64 {
        self.decode_proj_batch_us[0] + self.kv_transfer_us(ctx)
    }

    /// Kernel-derived projection cost of one decode batch of width `b`, µs:
    /// the batched LUT-GEMV cost model summed over every projection (and
    /// the lm head) — one shared bit-serial weight stream, per-lane table
    /// precompute and VLUT issues, one kernel launch. Batch widths beyond
    /// the precomputed KV-slot capacity are priced on demand.
    pub fn sim_decode_batch_proj_us(&self, b: usize) -> f64 {
        assert!(b > 0, "batch must hold at least one request");
        if let Some(&us) = self.decode_proj_batch_us.get(b - 1) {
            return us;
        }
        let npu = &self.soc.npu;
        let mut total = 0.0;
        for (m, k) in self.shape.proj_shapes() {
            total += tman_gemv_batched_latency_us(npu, m, k, self.fmt, b);
        }
        total + tman_gemv_batched_latency_us(npu, self.shape.vocab, self.shape.d_model, self.fmt, b)
    }

    /// Simulated on-device time for one *batched* decode step over requests
    /// at context lengths `ctxs`. The projection cost comes from the
    /// batched table-lookup kernel ([`Engine::sim_decode_batch_proj_us`]):
    /// one pass over the bit-serial weights serves the whole batch, each
    /// extra request adding only its table precompute, VLUT issues and
    /// accumulator traffic. Per-request KV attention traffic is not shared.
    /// For a single request this equals [`Engine::sim_decode_us`] exactly.
    pub fn sim_decode_batch_us(&self, ctxs: &[usize]) -> f64 {
        if ctxs.is_empty() {
            return 0.0;
        }
        let proj = self.sim_decode_batch_proj_us(ctxs.len());
        let kv: f64 = ctxs.iter().map(|&c| self.kv_transfer_us(c)).sum();
        proj + kv
    }

    /// Simulated on-device time for one prefill chunk ending at `ctx`.
    pub fn sim_prefill_chunk_us(&self, ctx: usize) -> f64 {
        // Chunk attention ~ chunk x ctx MACs on HMX; small at these sizes.
        let macs = 2.0 * (self.shape.n_layers * self.shape.chunk * ctx * self.shape.d_model) as f64;
        self.sim_prefill_chunk_us + macs / (self.soc.npu.hmx_tops_fp16 * 1e6)
    }

    // ---- step-level API (driven by the multi-request serving loop) ----

    /// Admit a request: acquire (and clear) a KV-cache slot for `id`.
    pub fn begin_request(&mut self, id: u64) -> Result<()> {
        self.backend.begin_request(id)
    }

    /// Re-attach a preempted request's KV slot, contents intact, so its
    /// prefill resumes where it stopped. Errors when `id` holds no slot.
    pub fn resume_request(&mut self, id: u64) -> Result<()> {
        self.backend.resume_request(id)
    }

    /// Release a finished request's KV-cache slot.
    pub fn end_request(&mut self, id: u64) {
        self.backend.end_request(id)
    }

    /// KV-cache slots currently held by admitted requests.
    pub fn kv_slots_in_use(&self) -> usize {
        self.backend.kv_slots_in_use()
    }

    /// Total KV-cache slots the backend can bind simultaneously.
    pub fn kv_slot_capacity(&self) -> usize {
        self.backend.kv_slot_capacity()
    }

    /// Run one prefill slice `[start, start + slice.len())` of request
    /// `id`. Exactly-`chunk`-sized slices go through the matrix path; the
    /// ragged tail is teacher-forced through the decode path (same
    /// numerics, per-token cost). Returns the logits at the last position
    /// and the simulated on-device µs.
    pub fn prefill_slice(
        &mut self,
        id: u64,
        slice: &[usize],
        start: usize,
    ) -> Result<(Vec<f32>, f64)> {
        anyhow::ensure!(!slice.is_empty(), "empty prefill slice");
        anyhow::ensure!(start + slice.len() <= self.shape.seq, "prefill past max_seq");
        if slice.len() == self.shape.chunk && self.backend.has_prefill() {
            let toks: Vec<i32> = slice.iter().map(|&t| t as i32).collect();
            let logits = self.backend.prefill_chunk(id, &toks, start as i32)?;
            let us = self.sim_prefill_chunk_us(start + slice.len());
            return Ok((logits, us));
        }
        let mut us = 0.0;
        let mut logits = Vec::new();
        let mut pos = start;
        for &t in slice {
            logits = self.backend.decode_step(id, t as i32, pos as i32)?;
            us += self.sim_decode_us(pos + 1);
            pos += 1;
        }
        Ok((logits, us))
    }

    /// Feed one generated token of request `id` at `pos`; returns the
    /// next-token logits and the simulated on-device µs for the step.
    pub fn decode_token(&mut self, id: u64, token: usize, pos: usize) -> Result<(Vec<f32>, f64)> {
        anyhow::ensure!(pos < self.shape.seq, "decode past max_seq");
        let logits = self.backend.decode_step(id, token as i32, pos as i32)?;
        let us = self.sim_decode_us(pos + 1);
        Ok((logits, us))
    }

    /// Run one decode step for every `(id, token, pos)` in the batch
    /// through the backend's *batched* forward — one shared pass over the
    /// weights, each request against its own KV slot, logits bit-identical
    /// to sequential single steps. Returns per-request logits (batch
    /// order) and per-request simulated µs: the kernel-derived batch cost
    /// ([`Engine::sim_decode_batch_us`]) attributed proportionally to each
    /// request's solo cost, so the attributions sum exactly to the batch
    /// total.
    pub fn decode_batch(
        &mut self,
        steps: &[(u64, usize, usize)],
    ) -> Result<(Vec<Vec<f32>>, Vec<f64>)> {
        anyhow::ensure!(!steps.is_empty(), "empty decode batch");
        let mut raw: Vec<DecodeStep> = Vec::with_capacity(steps.len());
        for &(id, token, pos) in steps {
            anyhow::ensure!(pos < self.shape.seq, "decode past max_seq for request {id}");
            raw.push((id, token as i32, pos as i32));
        }
        let logits = self.backend.decode_batch(&raw)?;
        let solo: Vec<f64> =
            steps.iter().map(|&(_, _, pos)| self.sim_decode_us(pos + 1)).collect();
        let ctxs: Vec<usize> = steps.iter().map(|&(_, _, pos)| pos + 1).collect();
        let total = self.sim_decode_batch_us(&ctxs);
        let solo_sum: f64 = solo.iter().sum();
        let per: Vec<f64> = solo.iter().map(|s| total * s / solo_sum).collect();
        Ok((logits, per))
    }

    /// Serve one request end to end (single-shot path; the serving loop in
    /// [`crate::coordinator::server`] drives the step API instead).
    pub fn generate(
        &mut self,
        prompt: &str,
        opts: &GenerateOpts,
    ) -> Result<(String, RequestMetrics)> {
        let prompt_tokens = tokenizer::encode(prompt);
        anyhow::ensure!(!prompt_tokens.is_empty(), "empty prompt");
        anyhow::ensure!(prompt_tokens.len() < self.shape.seq, "prompt exceeds max_seq");
        // Same budget rule as the serving loop: N generated tokens need
        // N - 1 decode forwards, so up to `seq - prompt` tokens fit.
        let budget = self.shape.seq.saturating_sub(prompt_tokens.len());
        let max_new = opts.max_new_tokens.min(budget);
        self.begin_request(GENERATE_REQ_ID)?;
        let chunk = self.shape.chunk;

        // ---- prefill: whole chunks through the matrix path, remainder
        // through the decode path (teacher forcing) ----
        let timer = PhaseTimer::start();
        let mut sim_prefill_us = 0.0;
        let mut pos = 0usize;
        let mut logits: Vec<f32> = Vec::new();
        while pos < prompt_tokens.len() {
            let rem = prompt_tokens.len() - pos;
            let len = if chunk == 0 { rem } else { chunk.min(rem) };
            let (l, us) = self.prefill_slice(GENERATE_REQ_ID, &prompt_tokens[pos..pos + len], pos)?;
            logits = l;
            sim_prefill_us += us;
            pos += len;
        }
        let wall_prefill_s = timer.stop();

        // ---- decode loop ----
        let timer = PhaseTimer::start();
        let mut sim_decode_us = 0.0;
        let mut rng = Rng::new(opts.seed);
        let mut out_tokens: Vec<usize> = Vec::new();
        for i in 0..max_new {
            let next = sampler::sample(&logits, opts.temperature, opts.top_k, &mut rng);
            // Check *before* emitting: the stop byte must not leak into the
            // decoded output. Compare in token space so vocabularies larger
            // than 256 (e.g. base-100m) cannot alias onto a stop byte.
            if opts.stop_byte.map(usize::from) == Some(next) {
                break;
            }
            out_tokens.push(next);
            // The last budgeted token needs no further forward: its logits
            // would never be sampled.
            if i + 1 == max_new {
                break;
            }
            let (l, us) = self.decode_token(GENERATE_REQ_ID, next, pos)?;
            logits = l;
            sim_decode_us += us;
            pos += 1;
        }
        let wall_decode_s = timer.stop();
        self.end_request(GENERATE_REQ_ID);

        let pm = &self.soc.power;
        let metrics = RequestMetrics {
            prompt_tokens: prompt_tokens.len(),
            generated_tokens: out_tokens.len(),
            wall_prefill_s,
            wall_decode_s,
            sim_prefill_s: sim_prefill_us / 1e6,
            sim_decode_s: sim_decode_us / 1e6,
            sim_prefill_j: sim_energy_j(
                pm,
                Placement::NpuOnly,
                sim_prefill_us / 1e6,
                prompt_tokens.len(),
            ),
            sim_decode_j: sim_energy_j(
                pm,
                Placement::NpuOnly,
                sim_decode_us / 1e6,
                out_tokens.len(),
            ),
        };
        Ok((tokenizer::decode(&out_tokens), metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::kv_cache::KvCache;
    use crate::model::weights::random_transformer;
    use crate::npu::config::SocConfig;

    fn engine(seed: u64) -> Engine {
        let model = random_transformer(&ModelConfig::tiny(), seed);
        Engine::reference(model, SocConfig::oneplus12(), 16, 4, 2).expect("engine")
    }

    #[test]
    fn reference_generate_is_deterministic_under_greedy() {
        let mut a = engine(3);
        let mut b = engine(3);
        let opts = GenerateOpts { max_new_tokens: 6, temperature: 0.0, ..Default::default() };
        let (ta, ma) = a.generate("lookup tables", &opts).expect("gen a");
        let (tb, _) = b.generate("lookup tables", &opts).expect("gen b");
        assert_eq!(ta, tb);
        assert_eq!(ma.generated_tokens, 6);
        assert!(ma.sim_prefill_s > 0.0 && ma.sim_decode_s > 0.0);
        assert!(ma.sim_prefill_j > 0.0 && ma.sim_decode_j > 0.0);
    }

    #[test]
    fn stop_byte_does_not_leak_into_output() {
        // Predict the first greedy token with the same weights, then ask the
        // engine to stop on exactly that byte: the output must be empty.
        let model = random_transformer(&ModelConfig::tiny(), 9);
        let prompt = tokenizer::encode("ab");
        let mut cache = KvCache::new(&model.cfg, 32);
        let mut logits = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            logits = model.forward_token(t, pos, &mut cache);
        }
        let first = sampler::greedy(&logits);

        let mut eng = engine(9);
        let opts = GenerateOpts {
            max_new_tokens: 8,
            temperature: 0.0,
            stop_byte: Some(first as u8),
            ..Default::default()
        };
        let (text, m) = eng.generate("ab", &opts).expect("gen");
        assert_eq!(m.generated_tokens, 0, "stop byte must not be emitted");
        assert!(text.is_empty());
        // The same engine without the stop byte generates normally.
        let opts = GenerateOpts { max_new_tokens: 8, temperature: 0.0, ..Default::default() };
        let (_, m) = eng.generate("ab", &opts).expect("gen");
        assert_eq!(m.generated_tokens, 8);
    }

    #[test]
    fn generation_respects_the_sequence_budget() {
        let mut eng = engine(5);
        let prompt: String = std::iter::repeat('x').take(250).collect();
        let opts = GenerateOpts { max_new_tokens: 20, temperature: 0.0, ..Default::default() };
        let (_, m) = eng.generate(&prompt, &opts).expect("gen");
        // tiny max_seq = 256: 250 prompt + at most 6 generated (the 6th
        // token needs no forward of its own).
        assert_eq!(m.prompt_tokens, 250);
        assert_eq!(m.generated_tokens, 6);
    }

    #[test]
    fn step_api_matches_generate_numerics() {
        // prefill_slice over chunk-sized + ragged slices must land on the
        // same logits as a fresh stepwise pass.
        let mut eng = engine(7);
        let toks = tokenizer::encode("the lookup table subsumes dequantization");
        eng.begin_request(1).expect("begin");
        let mut a = Vec::new();
        let mut pos = 0usize;
        while pos < toks.len() {
            let len = 16usize.min(toks.len() - pos);
            let (l, us) = eng.prefill_slice(1, &toks[pos..pos + len], pos).expect("slice");
            assert!(us > 0.0);
            a = l;
            pos += len;
        }
        eng.end_request(1);

        eng.begin_request(2).expect("begin");
        let mut b = Vec::new();
        for (p, &t) in toks.iter().enumerate() {
            let (l, _) = eng.decode_token(2, t, p).expect("step");
            b = l;
        }
        eng.end_request(2);
        assert_eq!(a, b);
    }

    #[test]
    fn decode_batch_matches_singles_and_shares_the_weight_pass() {
        // Batched decode must be numerically identical to per-request
        // single steps, cost less simulated time than the solo sum (one
        // weight pass amortized), and attribute exactly the batch total.
        let mut batched = engine(13);
        let mut solo = engine(13);
        for id in 1..=2u64 {
            batched.begin_request(id).expect("begin");
            solo.begin_request(id).expect("begin");
            let t = 64 + id as usize;
            batched.decode_token(id, t, 0).expect("ctx");
            solo.decode_token(id, t, 0).expect("ctx");
        }
        let steps = [(1u64, 97usize, 1usize), (2u64, 98, 1)];
        let (logits, per_us) = batched.decode_batch(&steps).expect("batch");
        let mut solo_sum = 0.0;
        for (i, &(id, tok, pos)) in steps.iter().enumerate() {
            let (l, us) = solo.decode_token(id, tok, pos).expect("single");
            assert_eq!(logits[i], l, "request {id}");
            solo_sum += us;
        }
        let total: f64 = per_us.iter().sum();
        assert!(total < solo_sum, "batch {total} must beat solo sum {solo_sum}");
        let want = batched.sim_decode_batch_us(&[2, 2]);
        assert!((total - want).abs() < 1e-9, "attribution must sum to the batch cost");
        // A singleton batch prices exactly like a solo step.
        let one = batched.sim_decode_batch_us(&[5]);
        assert!((one - batched.sim_decode_us(5)).abs() < 1e-12);
        // The kernel-derived projection cost amortizes the weight pass:
        // sublinear in the batch width, yet still growing with it.
        let p1 = batched.sim_decode_batch_proj_us(1);
        let p2 = batched.sim_decode_batch_proj_us(2);
        assert!(p2 > p1, "extra lanes are not free");
        assert!(p2 < 2.0 * p1, "the weight pass must be shared");
    }

    #[test]
    fn batch_widths_beyond_the_slot_capacity_price_consistently() {
        // The engine precomputes batch costs up to its KV-slot capacity (2
        // here); wider widths are priced on demand by the same kernel model
        // and must stay on the same monotone sub-linear curve.
        let eng = engine(3);
        let solo = eng.sim_decode_batch_proj_us(1);
        let mut prev = solo;
        for b in 2..=6usize {
            let us = eng.sim_decode_batch_proj_us(b);
            assert!(us >= prev, "width {b} regressed");
            assert!(us < b as f64 * solo, "width {b} lost the shared pass");
            prev = us;
        }
    }
}
