//! Canonical (unpacked) quantized weight matrix.
//!
//! `QuantizedMatrix` is the logical form every quantizer produces and every
//! packed layout (bit-serial, bit-parallel) is derived from. Codes are kept
//! one-per-element in `u8` here; the wire formats in `bitserial.rs` /
//! `bitparallel.rs` pack them for the kernels.

use crate::quant::formats::{Granularity, WeightDtype};
use crate::util::f16_round;

/// A quantized (M, K) weight matrix: M output channels, K input channels.
///
/// Dequantization of element (i, j):
/// `w = (code(i,j) as f32 - zero(g)) * scale(g)` with `g = gran.group_of(i,j)`.
/// Scales/zeros are stored rounded to fp16, matching on-device metadata.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub m: usize,
    pub k: usize,
    pub dtype: WeightDtype,
    pub gran: Granularity,
    /// Unsigned codes, row-major, one per element, in `[0, levels)`.
    pub codes: Vec<u8>,
    /// One scale per group (fp16-rounded).
    pub scales: Vec<f32>,
    /// One zero-point per group, in code space (fp16-rounded; e.g. 8.0 for
    /// symmetric INT4, arbitrary for asymmetric GPTQ-style blocks).
    pub zeros: Vec<f32>,
}

impl QuantizedMatrix {
    pub fn new(
        m: usize,
        k: usize,
        dtype: WeightDtype,
        gran: Granularity,
        codes: Vec<u8>,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> Self {
        assert_eq!(codes.len(), m * k, "codes length");
        let groups = gran.num_groups(m, k);
        assert_eq!(scales.len(), groups, "scales length");
        assert_eq!(zeros.len(), groups, "zeros length");
        let max = dtype.levels();
        debug_assert!(codes.iter().all(|&c| (c as u32) < max), "code out of range for {dtype}");
        let scales = scales.into_iter().map(f16_round).collect();
        let zeros = zeros.into_iter().map(f16_round).collect();
        Self { m, k, dtype, gran, codes, scales, zeros }
    }

    #[inline]
    pub fn code(&self, row: usize, col: usize) -> u8 {
        self.codes[row * self.k + col]
    }

    #[inline]
    pub fn group(&self, row: usize, col: usize) -> usize {
        self.gran.group_of(row, col, self.k)
    }

    /// Dequantize a single element to f32 (reference path; kernels use the
    /// packed layouts + LUTs instead).
    #[inline]
    pub fn dequant(&self, row: usize, col: usize) -> f32 {
        let g = self.group(row, col);
        (self.code(row, col) as f32 - self.zeros[g]) * self.scales[g]
    }

    /// Full dequantized matrix, row-major (M, K). Reference/oracle path.
    pub fn dequant_all(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m * self.k];
        for i in 0..self.m {
            for j in 0..self.k {
                out[i * self.k + j] = self.dequant(i, j);
            }
        }
        out
    }

    /// Dequantize one row (output channel) into `dst`.
    pub fn dequant_row(&self, row: usize, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.k);
        for (j, d) in dst.iter_mut().enumerate() {
            *d = self.dequant(row, j);
        }
    }

    /// Packed storage footprint in bytes (codes + fp16 scale/zero pairs).
    pub fn footprint_bytes(&self) -> usize {
        (self.m * self.k * self.dtype.bits() as usize).div_ceil(8) + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> QuantizedMatrix {
        // 2x4, INT4, per-block(2): groups per row = 2.
        QuantizedMatrix::new(
            2,
            4,
            WeightDtype::Int4,
            Granularity::PerBlock(2),
            vec![0, 15, 8, 8, 1, 2, 3, 4],
            vec![0.5, 1.0, 0.25, 2.0],
            vec![8.0, 8.0, 0.0, 2.0],
        )
    }

    #[test]
    fn element_dequant() {
        let q = tiny();
        assert_eq!(q.dequant(0, 0), (0.0 - 8.0) * 0.5);
        assert_eq!(q.dequant(0, 1), (15.0 - 8.0) * 0.5);
        assert_eq!(q.dequant(0, 2), 0.0);
        assert_eq!(q.dequant(1, 0), 1.0 * 0.25);
        assert_eq!(q.dequant(1, 3), (4.0 - 2.0) * 2.0);
    }

    #[test]
    fn dequant_all_matches_elementwise() {
        let q = tiny();
        let all = q.dequant_all();
        for i in 0..2 {
            for j in 0..4 {
                assert_eq!(all[i * 4 + j], q.dequant(i, j));
            }
        }
        let mut row = vec![0.0; 4];
        q.dequant_row(1, &mut row);
        assert_eq!(row, &all[4..8]);
    }

    #[test]
    fn footprint() {
        let q = tiny();
        // 8 codes * 4 bits = 4 bytes, 4 groups * 4 bytes = 16.
        assert_eq!(q.footprint_bytes(), 4 + 16);
    }

    #[test]
    #[should_panic(expected = "scales length")]
    fn wrong_scale_count_panics() {
        QuantizedMatrix::new(
            1,
            4,
            WeightDtype::Int4,
            Granularity::PerBlock(2),
            vec![0; 4],
            vec![1.0],
            vec![0.0],
        );
    }
}
