//! Simulated DDR/flash spill tier behind the paged KV pool.
//!
//! The hot arena ([`crate::kvpool::PagedKvPool`]) holds the blocks the NPU
//! computes against; this module models the *second* tier of the device
//! memory hierarchy — the DDR/flash capacity a radix eviction can spill a
//! cold block into instead of dropping it. A later prefix-cache lookup
//! that reaches a spilled block faults it back: the pool copies the saved
//! K/V into a freshly allocated hot block, bit-identical to the original
//! (fingerprint-checked), and the engine prices the fault as a DMA
//! transfer on the memory power rail — a warm-tier hit costs a copy, not
//! a re-prefill.
//!
//! Lifecycle discipline (wal3-style manifest + GC):
//!
//! ```text
//!   hot (radix)  --evict-->  SPILLED  --lookup fault-->  hot (radix)
//!        ^                     |  |
//!        |                     |  +--capacity LRU--> DROPPED
//!        +------publish--------+----------GC-------> RECLAIMED
//! ```
//!
//! Every transition appends a [`ManifestRecord`] to an append-only,
//! sequence-numbered log keyed by the block's *cumulative prefix key*
//! ([`crate::kvpool::prefix_block_keys`]) with its parent key as lineage —
//! replaying the log reproduces the live entry set exactly, which is what
//! [`SpillTier::audit`] asserts. A GC pass reclaims entries whose key went
//! hot again (a request recomputed or republished the same prefix, so the
//! tier copy is dead) and compacts the manifest down to the latest spill
//! record per live key, bounding the log the way wal3's collector bounds
//! its WAL.
//!
//! The tier is a *simulation* of capacity, not of a storage device: spill
//! and restore move bytes synchronously and only the restore is priced
//! (on the DMA/memory rail — spills ride the same eviction the pool
//! already performed). Numerics are untouched by construction: a restored
//! block's contents are the spilled block's contents, so tier-on/off
//! logits stay byte-identical.

use std::collections::{HashMap, HashSet};

/// Default warm-tier capacity as a multiple of the hot arena
/// (`tier_blocks = DEFAULT_TIER_FACTOR × hot blocks`): DDR/flash is an
/// order of magnitude larger than the NPU-reachable arena.
pub const DEFAULT_TIER_FACTOR: usize = 10;

/// One manifest transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierOp {
    /// A cold block entered the tier (radix eviction).
    Spill,
    /// A prefix lookup faulted the block back into the hot arena.
    Restore,
    /// Capacity pressure dropped the oldest entry, or a whole-cache clear
    /// dropped everything (the lossy paths).
    Drop,
    /// GC reclaimed an entry whose prefix went hot again.
    Gc,
}

/// Append-only log record: `seq` orders the log, `key` is the block's
/// cumulative prefix key, `parent` its predecessor block's key (lineage —
/// `None` for a prompt's first block), `bytes` the block's K+V payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ManifestRecord {
    pub seq: u64,
    pub op: TierOp,
    pub key: u64,
    pub parent: Option<u64>,
    pub bytes: usize,
}

/// One spilled block: the tokens of its own (last) block run, its K/V
/// payload, and a content fingerprint the pool re-checks on restore.
#[derive(Debug, Clone)]
struct TierEntry {
    tokens: Vec<usize>,
    k: Vec<f32>,
    v: Vec<f32>,
    fingerprint: u64,
    parent: Option<u64>,
    /// Manifest seq of the spill that wrote this entry — the tier's LRU
    /// order under capacity pressure.
    spill_seq: u64,
    bytes: usize,
}

/// Tier counters surfaced through [`crate::kvpool::KvPoolStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub capacity_blocks: usize,
    pub resident_blocks: usize,
    pub spills: usize,
    pub restores: usize,
    pub restored_bytes: usize,
    pub dropped: usize,
    pub gc_reclaimed: usize,
}

/// The simulated DDR/flash tier: a capacity-bounded map from cumulative
/// prefix key to spilled block, plus the manifest log.
#[derive(Debug, Clone)]
pub struct SpillTier {
    capacity_blocks: usize,
    entries: HashMap<u64, TierEntry>,
    manifest: Vec<ManifestRecord>,
    seq: u64,
    stats: TierStats,
}

impl SpillTier {
    pub fn new(capacity_blocks: usize) -> Self {
        assert!(capacity_blocks > 0, "tier needs at least one block of capacity");
        Self {
            capacity_blocks,
            entries: HashMap::new(),
            manifest: Vec::new(),
            seq: 0,
            stats: TierStats { capacity_blocks, ..Default::default() },
        }
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn resident_blocks(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> TierStats {
        TierStats { resident_blocks: self.entries.len(), ..self.stats }
    }

    /// The manifest log (diagnostics/tests).
    pub fn manifest(&self) -> &[ManifestRecord] {
        &self.manifest
    }

    fn record(&mut self, op: TierOp, key: u64, parent: Option<u64>, bytes: usize) {
        self.seq += 1;
        self.manifest.push(ManifestRecord { seq: self.seq, op, key, parent, bytes });
    }

    /// Whether the tier holds any entry under `key` (disjointness audits).
    pub fn contains_key(&self, key: u64) -> bool {
        self.entries.contains_key(&key)
    }

    /// Whether the tier holds `key` with exactly these block tokens — the
    /// pre-restore check a prefix fault performs before allocating a hot
    /// block (a content mismatch under a colliding key is a miss, never a
    /// wrong restore).
    pub fn contains_tokens(&self, key: u64, tokens: &[usize]) -> bool {
        self.entries.get(&key).is_some_and(|e| e.tokens == tokens)
    }

    /// Spill one evicted block into the tier. A re-spill of the same key
    /// supersedes the old entry (same prefix, freshest contents); capacity
    /// pressure drops the oldest entry by spill order.
    #[allow(clippy::too_many_arguments)]
    pub fn spill(
        &mut self,
        key: u64,
        parent: Option<u64>,
        tokens: Vec<usize>,
        k: Vec<f32>,
        v: Vec<f32>,
        fingerprint: u64,
        bytes: usize,
    ) {
        if !self.entries.contains_key(&key) && self.entries.len() >= self.capacity_blocks {
            // Oldest spill goes first — the tier's own LRU.
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.spill_seq)
                .map(|(&vk, _)| vk)
                .expect("non-empty tier at capacity");
            let dropped = self.entries.remove(&victim).expect("victim resident");
            self.record(TierOp::Drop, victim, dropped.parent, dropped.bytes);
            self.stats.dropped += 1;
        }
        self.record(TierOp::Spill, key, parent, bytes);
        let spill_seq = self.seq;
        self.entries.insert(key, TierEntry { tokens, k, v, fingerprint, parent, spill_seq, bytes });
        self.stats.spills += 1;
    }

    /// Fault `key` back out of the tier (move semantics: the entry leaves
    /// the tier — the hot arena owns the block again). Returns the K/V
    /// payload and its fingerprint, or `None` when the tier does not hold
    /// exactly these tokens under `key`.
    pub fn restore(&mut self, key: u64, tokens: &[usize]) -> Option<(Vec<f32>, Vec<f32>, u64)> {
        if !self.contains_tokens(key, tokens) {
            return None;
        }
        let e = self.entries.remove(&key).expect("checked resident");
        self.record(TierOp::Restore, key, e.parent, e.bytes);
        self.stats.restores += 1;
        self.stats.restored_bytes += e.bytes;
        Some((e.k, e.v, e.fingerprint))
    }

    /// Drop every entry (whole-cache clear — the tier analogue of
    /// [`crate::kvpool::PagedKvPool::clear_prefix_index`]).
    pub fn clear(&mut self) {
        let mut keys: Vec<u64> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        for key in keys {
            let e = self.entries.remove(&key).expect("listed key resident");
            self.record(TierOp::Drop, key, e.parent, e.bytes);
            self.stats.dropped += 1;
        }
    }

    /// Reclaim every entry whose prefix key is hot again (`hot` holds the
    /// radix index's current cumulative keys): the hot copy is canonical,
    /// so the tier copy is dead. Then compact the manifest to the latest
    /// spill record per surviving key — replay stays exact while the log
    /// stops growing with history.
    pub fn gc(&mut self, hot: &HashSet<u64>) {
        let dead: Vec<u64> = self.entries.keys().filter(|k| hot.contains(k)).copied().collect();
        for key in dead {
            let e = self.entries.remove(&key).expect("dead key resident");
            self.record(TierOp::Gc, key, e.parent, e.bytes);
            self.stats.gc_reclaimed += 1;
        }
        // Compaction: one Spill record per live entry (its latest), in seq
        // order. Replaying the compacted log yields the same live set.
        let mut compact: Vec<ManifestRecord> = self
            .entries
            .iter()
            .map(|(&key, e)| ManifestRecord {
                seq: e.spill_seq,
                op: TierOp::Spill,
                key,
                parent: e.parent,
                bytes: e.bytes,
            })
            .collect();
        compact.sort_unstable_by_key(|r| r.seq);
        self.manifest = compact;
    }

    /// Replay the manifest and assert the reconstructed live set matches
    /// the resident entries — the tier's analogue of the pool's refcount
    /// audit. Panics on divergence (test/debug invariant).
    pub fn audit(&self) {
        let mut live: HashMap<u64, u64> = HashMap::new(); // key -> spill seq
        let mut last_seq = 0u64;
        for r in &self.manifest {
            assert!(r.seq > last_seq, "manifest seq must be strictly increasing");
            last_seq = r.seq;
            match r.op {
                TierOp::Spill => {
                    live.insert(r.key, r.seq);
                }
                TierOp::Restore | TierOp::Drop | TierOp::Gc => {
                    assert!(
                        live.remove(&r.key).is_some(),
                        "manifest removes key {:#x} that was never live",
                        r.key
                    );
                }
            }
        }
        assert_eq!(live.len(), self.entries.len(), "manifest replay diverged from entries");
        for (key, e) in &self.entries {
            assert_eq!(
                live.get(key),
                Some(&e.spill_seq),
                "entry {key:#x} missing from (or stale in) the manifest replay"
            );
        }
        assert!(self.entries.len() <= self.capacity_blocks, "tier over capacity");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(tag: f32, n: usize) -> (Vec<f32>, Vec<f32>) {
        ((0..n).map(|i| tag + i as f32).collect(), (0..n).map(|i| -tag - i as f32).collect())
    }

    #[test]
    fn spill_restore_round_trip_is_exact() {
        let mut t = SpillTier::new(4);
        let (k, v) = payload(1.0, 8);
        t.spill(0xA, None, vec![1, 2], k.clone(), v.clone(), 77, 64);
        assert_eq!(t.resident_blocks(), 1);
        assert!(t.contains_tokens(0xA, &[1, 2]));
        assert!(!t.contains_tokens(0xA, &[1, 3]), "token mismatch is a miss");
        assert!(t.restore(0xA, &[1, 3]).is_none(), "mismatched restore must refuse");
        let (rk, rv, fp) = t.restore(0xA, &[1, 2]).expect("hit");
        assert_eq!((rk, rv, fp), (k, v, 77));
        assert_eq!(t.resident_blocks(), 0, "restore moves the entry out");
        let s = t.stats();
        assert_eq!((s.spills, s.restores, s.restored_bytes), (1, 1, 64));
        t.audit();
    }

    #[test]
    fn capacity_pressure_drops_oldest_spill_first() {
        let mut t = SpillTier::new(2);
        for (i, key) in [0x1u64, 0x2, 0x3].into_iter().enumerate() {
            let (k, v) = payload(i as f32, 4);
            t.spill(key, None, vec![i], k, v, i as u64, 32);
        }
        assert_eq!(t.resident_blocks(), 2);
        assert!(!t.contains_tokens(0x1, &[0]), "oldest entry dropped");
        assert!(t.contains_tokens(0x2, &[1]));
        assert!(t.contains_tokens(0x3, &[2]));
        assert_eq!(t.stats().dropped, 1);
        t.audit();
    }

    #[test]
    fn respill_supersedes_without_dropping() {
        let mut t = SpillTier::new(1);
        let (k, v) = payload(0.0, 4);
        t.spill(0x9, None, vec![5], k, v, 1, 32);
        let (k2, v2) = payload(9.0, 4);
        t.spill(0x9, None, vec![5], k2.clone(), v2.clone(), 2, 32);
        assert_eq!(t.resident_blocks(), 1);
        assert_eq!(t.stats().dropped, 0, "same-key re-spill is a supersede, not a drop");
        let (rk, rv, fp) = t.restore(0x9, &[5]).expect("hit");
        assert_eq!((rk, rv, fp), (k2, v2, 2), "freshest contents win");
        t.audit();
    }

    #[test]
    fn gc_reclaims_hot_keys_and_compacts_the_manifest() {
        let mut t = SpillTier::new(4);
        for key in [0x1u64, 0x2, 0x3] {
            let (k, v) = payload(key as f32, 4);
            t.spill(key, Some(key - 1), vec![key as usize], k, v, key, 32);
        }
        let hot: HashSet<u64> = [0x2u64].into_iter().collect();
        t.gc(&hot);
        assert!(!t.contains_tokens(0x2, &[2]), "hot key reclaimed");
        assert_eq!(t.stats().gc_reclaimed, 1);
        assert_eq!(t.resident_blocks(), 2);
        assert_eq!(t.manifest().len(), 2, "compacted to one spill record per live entry");
        assert!(t.manifest().iter().all(|r| r.op == TierOp::Spill));
        t.audit();
        // GC with nothing hot is a no-op beyond compaction.
        t.gc(&HashSet::new());
        assert_eq!(t.resident_blocks(), 2);
        t.audit();
    }

    #[test]
    fn manifest_replay_tracks_every_transition() {
        let mut t = SpillTier::new(2);
        let (k, v) = payload(0.0, 4);
        t.spill(0xA, None, vec![1], k.clone(), v.clone(), 0, 32);
        t.spill(0xB, Some(0xA), vec![2], k.clone(), v.clone(), 0, 32);
        t.audit();
        t.restore(0xA, &[1]).expect("hit");
        t.audit();
        t.spill(0xC, Some(0xB), vec![3], k.clone(), v, 0, 32);
        t.spill(0xD, Some(0xC), vec![4], k.clone(), k, 0, 32); // drops 0xB (oldest)
        assert_eq!(t.stats().dropped, 1);
        t.audit();
    }
}
