//! Load-generation harness: composable synthetic serving load.
//!
//! A load is the product of three independent axes:
//!
//! - an [`ArrivalProcess`] — *when* requests arrive (steady Poisson,
//!   on/off bursts, a diurnal cycle, or a flash crowd);
//! - a [`TraceProfile`] — *what* they ask for (prompt/output length
//!   mixes, shared system prefixes, interactive-vs-batch class split);
//! - a per-class SLO and fan-out — *how* they must be served
//!   (TTFT deadlines on the interactive class, TTC-style sibling
//!   requests sharing one prompt).
//!
//! [`LoadSpec`] glues the axes together and emits an open-loop
//! [`TraceRequest`] trace, deterministic under its seed, that feeds the
//! same [`crate::coordinator::server::Server`] entry points the legacy
//! synthetic trace does. [`serving_snapshot`] runs a pinned set of these
//! loads and prints the flat `BENCH_serving.json` document CI tracks.

mod arrivals;
mod snapshot;

pub use arrivals::ArrivalProcess;
pub use snapshot::serving_snapshot;

use crate::coordinator::server::{profile_request, TraceProfile, TraceRequest};
use crate::util::Rng;

/// A complete load model: arrival process × workload mix × SLO × fan-out.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// When request groups arrive.
    pub process: ArrivalProcess,
    /// What each request asks for.
    pub profile: TraceProfile,
    /// TTFT slack (µs) stamped on every interactive request; overrides
    /// the profile's own setting when `Some`.
    pub interactive_slo_us: Option<f64>,
    /// Requests per arrival: each arrival spawns `fanout` sibling
    /// requests sharing one prompt, class, and deadline (test-time-compute
    /// style sampling fan-out; 1 = plain serving).
    pub fanout: usize,
}

impl LoadSpec {
    pub fn new(process: ArrivalProcess, profile: TraceProfile) -> Self {
        Self { process, profile, interactive_slo_us: None, fanout: 1 }
    }

    /// Stamp a TTFT deadline of `us` µs of slack on interactive requests.
    pub fn with_slo(mut self, us: f64) -> Self {
        self.interactive_slo_us = Some(us);
        self
    }

    /// Spawn `k` sibling requests per arrival.
    pub fn with_fanout(mut self, k: usize) -> Self {
        self.fanout = k.max(1);
        self
    }

    /// Generate exactly `n` requests, deterministically under `seed`.
    ///
    /// Arrivals are drawn per *group* of `fanout` siblings; siblings share
    /// the group's prompt, priority, budget, and deadline, staggered 1 ns
    /// apart so arrival order stays strict. Ids are 1-based and dense.
    pub fn trace(&self, n: usize, seed: u64) -> Vec<TraceRequest> {
        let mut profile = self.profile.clone();
        if self.interactive_slo_us.is_some() {
            profile.interactive_slo_us = self.interactive_slo_us;
        }
        let k = self.fanout.max(1);
        let groups = n.div_ceil(k);
        let mut rng = Rng::new(seed);
        let times = self.process.times(groups, &mut rng);
        let mut out: Vec<TraceRequest> = Vec::with_capacity(n);
        for (g, &t) in times.iter().enumerate() {
            let base = profile_request(g as u64, t, &mut rng, &profile);
            for s in 0..k {
                if out.len() == n {
                    break;
                }
                out.push(TraceRequest {
                    id: out.len() as u64 + 1,
                    arrival_us: t + s as f64 * 1e-3,
                    priority: base.priority,
                    prompt: base.prompt.clone(),
                    max_new_tokens: base.max_new_tokens,
                    ttft_deadline_us: base.ttft_deadline_us,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_exactly_sized() {
        let spec = LoadSpec::new(ArrivalProcess::bursty(400.0), TraceProfile::tiny());
        for n in [1, 7, 32] {
            let a = spec.trace(n, 42);
            let b = spec.trace(n, 42);
            assert_eq!(a, b, "same seed must reproduce the trace");
            assert_eq!(a.len(), n);
            let ids: Vec<u64> = a.iter().map(|r| r.id).collect();
            assert_eq!(ids, (1..=n as u64).collect::<Vec<_>>(), "ids dense and 1-based");
            assert!(
                a.windows(2).all(|w| w[0].arrival_us < w[1].arrival_us),
                "arrivals strictly increasing"
            );
        }
        assert_ne!(spec.trace(16, 42), spec.trace(16, 43), "seed must matter");
    }

    #[test]
    fn fanout_siblings_share_prompt_class_and_deadline() {
        let spec = LoadSpec::new(
            ArrivalProcess::Poisson { mean_gap_us: 800.0 },
            TraceProfile::tiny(),
        )
        .with_slo(5_000.0)
        .with_fanout(4);
        let trace = spec.trace(24, 9);
        assert_eq!(trace.len(), 24);
        for group in trace.chunks(4) {
            let first = &group[0];
            for (s, r) in group.iter().enumerate() {
                assert_eq!(r.prompt, first.prompt, "siblings share the prompt");
                assert_eq!(r.priority, first.priority, "siblings share the class");
                assert_eq!(r.max_new_tokens, first.max_new_tokens);
                assert_eq!(r.ttft_deadline_us, first.ttft_deadline_us);
                let stagger = r.arrival_us - first.arrival_us;
                assert!(
                    (stagger - s as f64 * 1e-3).abs() < 1e-12,
                    "siblings staggered 1 ns apart"
                );
            }
        }
    }

    #[test]
    fn slo_override_stamps_interactive_requests_only() {
        let spec = LoadSpec::new(
            ArrivalProcess::Poisson { mean_gap_us: 500.0 },
            TraceProfile::tiny(),
        )
        .with_slo(3_000.0);
        let trace = spec.trace(64, 4);
        let (interactive, batch): (Vec<_>, Vec<_>) =
            trace.iter().partition(|r| r.priority == 0);
        assert!(!interactive.is_empty() && !batch.is_empty(), "mix draws both classes");
        assert!(interactive.iter().all(|r| r.ttft_deadline_us == Some(3_000.0)));
        assert!(batch.iter().all(|r| r.ttft_deadline_us.is_none()));

        // Stamping the SLO changes deadlines only: prompts, classes, and
        // arrivals are byte-identical to the unstamped trace.
        let plain = LoadSpec::new(
            ArrivalProcess::Poisson { mean_gap_us: 500.0 },
            TraceProfile::tiny(),
        )
        .trace(64, 4);
        for (a, b) in trace.iter().zip(&plain) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.arrival_us, b.arrival_us);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
        }
    }
}
