//! Baseline framework models — the five systems the paper compares against
//! (§6.1): llama.cpp, T-MAC, bitnet.cpp (CPU-only), QNN (NPU, hardware
//! formats only), and llm.npu (hybrid NPU prefill + CPU decode).
//!
//! Each baseline is an analytical kernel-latency model on the same SoC
//! description the T-MAN kernels use, calibrated to the paper's own
//! measurements (Fig. 5 breakdown, Table 2 bandwidths, §6.2–6.3 relative
//! results). Functional correctness paths reuse `kernels::reference`.

use crate::npu::config::SocConfig;
use crate::npu::cost::Breakdown;
use crate::npu::energy::Placement;
use crate::npu::hmx::{self, HmxPrecision};
use crate::npu::memory::LoadMethod;
use crate::quant::formats::{ActDtype, Granularity, QuantFormat, WeightDtype};

/// Every framework the evaluation sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    TMan,
    LlamaCpp,
    TMac,
    BitnetCpp,
    LlmNpu,
    Qnn,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::TMan => "T-MAN",
            Framework::LlamaCpp => "llama.cpp",
            Framework::TMac => "T-MAC",
            Framework::BitnetCpp => "bitnet.cpp",
            Framework::LlmNpu => "llm.npu",
            Framework::Qnn => "QNN",
        }
    }

    pub fn all() -> [Framework; 6] {
        [
            Framework::TMan,
            Framework::LlamaCpp,
            Framework::TMac,
            Framework::BitnetCpp,
            Framework::LlmNpu,
            Framework::Qnn,
        ]
    }

    /// Where each phase runs (drives the Table 3 energy model).
    pub fn placement(self, phase: Phase) -> Placement {
        match (self, phase) {
            (Framework::TMan, _) | (Framework::Qnn, _) => Placement::NpuOnly,
            (Framework::LlamaCpp, _) | (Framework::TMac, _) | (Framework::BitnetCpp, _) => {
                Placement::CpuOnly
            }
            // llm.npu: NPU prefill with CPU outlier cores hot; CPU decode.
            (Framework::LlmNpu, Phase::Prefill) => Placement::Hybrid,
            (Framework::LlmNpu, Phase::Decode) => Placement::Hybrid,
        }
    }

    /// Which quantization formats the framework can express (§6.1).
    pub fn supports(self, fmt: QuantFormat) -> bool {
        match self {
            // T-MAN: per-group, per-channel, per-tensor; 1.58/2/4-bit.
            Framework::TMan => fmt.weight.is_quantized() && fmt.weight != WeightDtype::Int8,
            // llama.cpp / T-MAC: per-group CPU kernels (and coarser).
            Framework::LlamaCpp | Framework::TMac => {
                matches!(fmt.weight, WeightDtype::Int4 | WeightDtype::Int2 | WeightDtype::Ternary)
            }
            // bitnet.cpp: ternary per-tensor only.
            Framework::BitnetCpp => {
                fmt.weight == WeightDtype::Ternary && fmt.gran == Granularity::PerTensor
            }
            // llm.npu: per-tensor INT8 prefill / INT4 decode.
            Framework::LlmNpu => {
                fmt.gran == Granularity::PerTensor
                    && matches!(fmt.weight, WeightDtype::Int8 | WeightDtype::Int4 | WeightDtype::Ternary)
            }
            // QNN: per-channel / per-tensor only — per-group is the gap
            // T-MAN fills (§6, Table 4).
            Framework::Qnn => {
                matches!(fmt.gran, Granularity::PerChannel | Granularity::PerTensor)
            }
        }
    }

    /// Bytes of weight storage the framework keeps resident for one (m, k)
    /// projection — llm.npu stores TWO copies (INT8 prefill + INT4 decode),
    /// which is what OOMs 8B models on 12 GB devices (§6.3).
    pub fn resident_weight_bytes(self, m: usize, k: usize, fmt: QuantFormat) -> usize {
        match self {
            Framework::LlmNpu => {
                QuantFormat::llmnpu_prefill().weight_footprint(m, k)
                    + QuantFormat::llmnpu_decode().weight_footprint(m, k)
            }
            Framework::Qnn => {
                // Per-channel INT4 (or FP16 when unquantized).
                let f = if fmt.weight.is_quantized() { QuantFormat::qnn_w4a16() } else { fmt };
                f.weight_footprint(m, k)
            }
            _ => fmt.weight_footprint(m, k),
        }
    }
}

/// Inference phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

// --------------------------------------------------------------------------
// CPU baselines
// --------------------------------------------------------------------------

/// llama.cpp-style CPU mpGEMV: stream packed weights, dequantize on NEON,
/// dot-product. MEM / DQ / CMP decomposition per Fig. 5 (right bar).
pub fn cpu_dequant_gemv(soc: &SocConfig, m: usize, k: usize, fmt: QuantFormat) -> Breakdown {
    let cpu = &soc.cpu;
    let bits = fmt.weight.bits() as usize;
    let bytes = (m * k * bits).div_ceil(8) + fmt.gran.num_groups(m, k) * 4;
    let mem_us = bytes as f64 / (cpu.mem_gbps * 1e3);
    let elems = (m * k) as f64;
    // Dequant: ~1 op per element at the CPU's elementwise-float rate.
    let dq_us = elems / (cpu.dequant_gops * 1e3);
    // MACs at the SIMD fma rate.
    let cmp_us = 2.0 * elems / (cpu.gemm_gops * 1e3);
    // CPU overlaps loads with compute imperfectly; keep stages additive as
    // the paper's Fig. 5 does.
    Breakdown { mem_us, dq_us, cmp_us, overhead_us: 1.0 }
}

/// T-MAC-style CPU LUT GEMV: no dequantization; TBL lookups at the NEON
/// table-lookup rate; memory traffic identical to llama.cpp's packed bytes.
pub fn cpu_lut_gemv(soc: &SocConfig, m: usize, k: usize, fmt: QuantFormat) -> Breakdown {
    let cpu = &soc.cpu;
    let bits = fmt.weight.bits() as usize;
    let bytes = (m * k * bits).div_ceil(8) + fmt.gran.num_groups(m, k) * 4;
    let mem_us = bytes as f64 / (cpu.mem_gbps * 1e3);
    // One lookup per 4 one-bit weights per plane.
    let lookups = (m * k * bits) as f64 / 4.0;
    let cmp_us = lookups / (cpu.tbl_glookups * 1e3);
    // Table precompute: 15 adds per 4 activations (vectorized) — small.
    let dq_us = (k as f64 / 4.0 * 15.0) / (cpu.gemm_gops * 1e3);
    Breakdown { mem_us, dq_us, cmp_us, overhead_us: 1.0 }
}

/// bitnet.cpp ternary CPU GEMV: specialized 2-bit kernel, modeled as the
/// T-MAC LUT path at 2 bits (its kernels share the TL lineage).
pub fn bitnet_cpu_gemv(soc: &SocConfig, m: usize, k: usize) -> Breakdown {
    cpu_lut_gemv(soc, m, k, QuantFormat::new(WeightDtype::Ternary, ActDtype::Int8, Granularity::PerTensor))
}

/// CPU mpGEMM (prefill on CPU for the CPU-only frameworks): dequant once,
/// then dense GEMM at the CPU rate — dominated by compute at n=128.
pub fn cpu_gemm(soc: &SocConfig, n: usize, m: usize, k: usize, fmt: QuantFormat) -> Breakdown {
    let cpu = &soc.cpu;
    let bits = fmt.weight.bits() as usize;
    let bytes = (m * k * bits).div_ceil(8);
    let mem_us = bytes as f64 / (cpu.mem_gbps * 1e3);
    let dq_us = (m * k) as f64 / (cpu.dequant_gops * 1e3);
    let cmp_us = 2.0 * (n * m * k) as f64 / (cpu.gemm_gops * 1e3);
    Breakdown { mem_us, dq_us, cmp_us, overhead_us: 1.0 }
}

// --------------------------------------------------------------------------
// QNN (NPU, hardware formats)
// --------------------------------------------------------------------------

/// QNN NPU GEMV. Per-channel INT4 / per-tensor formats run natively (no
/// dequant stage); FP16 streams 16-bit weights. Decode is bandwidth-bound,
/// and QNN's generic graph executor loads weights through the l2fetch path
/// (26–32 GB/s) rather than the hand-tuned DDR→TCM DMA (59 GB/s) that
/// T-MAN's custom kernels use — the Table 2 analysis is exactly the
/// optimization QNN's closed kernels leave on the table, and the source of
/// the paper's 1.5–1.8× end-to-end decode gap at equal bit width (§6.3).
pub fn qnn_gemv(soc: &SocConfig, m: usize, k: usize, fmt: QuantFormat) -> Breakdown {
    let npu = &soc.npu;
    let wbits = if fmt.weight.is_quantized() { fmt.weight.bits() as usize } else { 16 };
    // Per-channel scales: m pairs — negligible vs per-block.
    let bytes = (m * k * wbits).div_ceil(8) + m * 4;
    let mem_us = LoadMethod::L2Fetch.transfer_us(npu, bytes, npu.hvx_contexts);
    // Native-format MAC on the vector cores (HMX idle at n=1): INT8-class
    // vector MACs, 2 bytes/lane.
    let lanes = npu.hvx_vector_bytes / 2;
    let instrs = (m * k).div_ceil(lanes);
    let cmp_us = instrs as f64 * npu.valu_cpi / npu.hvx_contexts as f64 * npu.cycle_us();
    Breakdown { mem_us, dq_us: 0.0, cmp_us, overhead_us: 2.0 }
}

/// QNN NPU GEMM (prefill): native INT8/FP16 HMX, weights streamed by DMA,
/// no dequant stage; DMA and HMX overlap (QNN pipelines internally).
pub fn qnn_gemm(soc: &SocConfig, n: usize, m: usize, k: usize, fmt: QuantFormat) -> Breakdown {
    let npu = &soc.npu;
    let (wbits, prec) = if fmt.weight.is_quantized() {
        (fmt.weight.bits() as usize, HmxPrecision::Int8)
    } else {
        (16, HmxPrecision::Fp16)
    };
    let bytes = (m * k * wbits).div_ceil(8) + m * 4;
    let mem_us = LoadMethod::Dma.transfer_us(npu, bytes, 1);
    let cmp_us = hmx::hmx_gemm_time_us(npu, n, m, k, prec);
    Breakdown { mem_us, dq_us: 0.0, cmp_us, overhead_us: 2.0 }
}

/// QNN decode/prefill latency (overlapped mem/compute).
pub fn qnn_latency_us(b: &Breakdown) -> f64 {
    b.mem_us.max(b.cmp_us) + b.dq_us + b.overhead_us
}

// --------------------------------------------------------------------------
// llm.npu (hybrid)
// --------------------------------------------------------------------------

/// llm.npu decode: INT4 weights dequantized to INT8 on the **CPU** (it
/// cannot keep GEMV on the NPU), plus a per-kernel NPU↔CPU handoff that
/// makes it "fail to accelerate the decoding kernel" (§6.2).
pub fn llmnpu_gemv(soc: &SocConfig, m: usize, k: usize) -> Breakdown {
    let mut b = cpu_dequant_gemv(soc, m, k, QuantFormat::llmnpu_decode());
    b.overhead_us += soc.npu_cpu_sync_us; // outlier-offload communication
    b
}

/// llm.npu prefill: per-tensor INT8 GEMM on the HMX plus parallel CPU
/// outlier GEMV and a synchronization per chunk.
pub fn llmnpu_gemm(soc: &SocConfig, n: usize, m: usize, k: usize) -> Breakdown {
    let npu = &soc.npu;
    let bytes = m * k; // INT8 copy
    let mem_us = LoadMethod::Dma.transfer_us(npu, bytes, 1);
    let cmp_us = hmx::hmx_gemm_time_us(npu, n, m, k, HmxPrecision::Int8);
    // Outlier channels (~1%) on CPU, overlapped but joined per chunk.
    let outlier_us = 2.0 * (n * m * k / 100) as f64 / (soc.cpu.gemm_gops * 1e3);
    let join = soc.npu_cpu_sync_us / 4.0; // chunk-level sync amortized
    Breakdown { mem_us, dq_us: 0.0, cmp_us: cmp_us.max(outlier_us), overhead_us: 2.0 + join }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dequant_gemm::tman_gemm_latency_us;
    use crate::kernels::lut_gemv::tman_gemv_latency_us;

    fn soc() -> SocConfig {
        SocConfig::oneplus12()
    }

    #[test]
    fn fig5_npu_convertdq_slower_than_cpu() {
        // Fig. 5: W4A16 4096x4096 GEMV runs ~3.8x slower on the NPU (naive
        // dequant) than on the CPU; the DQ stage dominates on the NPU.
        use crate::kernels::dequant_gemm::{tile_cost_shape, DequantStrategy};
        use crate::kernels::tiling;
        let s = soc();
        let fmt = QuantFormat::tman_w4a16();
        let cpu = cpu_dequant_gemv(&s, 4096, 4096, fmt);
        let t = tiling::search(&s.npu, fmt, 4096, 4096, 1);
        let tile = tile_cost_shape(&s.npu, &t, 1, 4096, 4096, fmt, DequantStrategy::ConvertDq, s.npu.hvx_contexts);
        let tiles = crate::kernels::dequant_gemm::num_tiles_shape(&t, 4096, 4096) as f64;
        let npu_total = tile.sequential_us() * tiles;
        let cpu_total = cpu.sequential_us();
        let ratio = npu_total / cpu_total;
        assert!(ratio > 2.0 && ratio < 8.0, "NPU/CPU naive-dequant GEMV ratio {ratio} (paper: 3.8x)");
        // DQ dominates the NPU bar.
        assert!(tile.dq_us > tile.mem_us && tile.dq_us > tile.cmp_us);
    }

    #[test]
    fn tmac_beats_llamacpp_on_cpu() {
        let s = soc();
        let fmt = QuantFormat::tman_w4a16();
        let lc = cpu_dequant_gemv(&s, 4096, 4096, fmt).sequential_us();
        let tm = cpu_lut_gemv(&s, 4096, 4096, fmt).sequential_us();
        assert!(tm < lc, "T-MAC {tm} !< llama.cpp {lc}");
    }

    #[test]
    fn tman_decode_beats_qnn_fp16_by_large_factor() {
        // §6.2: up to 8x vs QNN-FP16 (weights are 8x smaller at 2 bits).
        let s = soc();
        let tman2 = tman_gemv_latency_us(&s.npu, 4096, 4096, QuantFormat::tman_w2a16());
        let qnn16 = qnn_latency_us(&qnn_gemv(&s, 4096, 4096, QuantFormat::qnn_fp16()));
        let ratio = qnn16 / tman2;
        assert!(ratio > 4.0 && ratio < 13.0, "T-MAN W2 vs QNN FP16 {ratio} (paper: up to 8x; ours overshoots slightly)");
    }

    #[test]
    fn tman_decode_vs_qnn_w4_comparable_and_w2_faster() {
        // §6.2: ~parity on 4-bit despite finer granularity; 1.8-2.5x on 2-bit.
        let s = soc();
        let tman4 = tman_gemv_latency_us(&s.npu, 4096, 4096, QuantFormat::tman_w4a16());
        let tman2 = tman_gemv_latency_us(&s.npu, 4096, 4096, QuantFormat::tman_w2a16());
        let qnn4 = qnn_latency_us(&qnn_gemv(&s, 4096, 4096, QuantFormat::qnn_w4a16()));
        let parity = tman4 / qnn4;
        assert!(parity < 1.3, "T-MAN W4 vs QNN W4 ratio {parity} (paper: similar)");
        let w2_speedup = qnn4 / tman2;
        assert!(w2_speedup > 1.5 && w2_speedup < 3.0, "QNN-W4/T-MAN-W2 {w2_speedup} (paper: 1.8-2.5x)");
    }

    #[test]
    fn llmnpu_decode_fails_to_accelerate() {
        // §6.2: llm.npu falls back to CPU + sync for decode; worse than
        // plain CPU and far worse than T-MAN.
        let s = soc();
        let llm = llmnpu_gemv(&s, 4096, 4096).sequential_us();
        let cpu = cpu_dequant_gemv(&s, 4096, 4096, QuantFormat::llmnpu_decode()).sequential_us();
        let tman = tman_gemv_latency_us(&s.npu, 4096, 4096, QuantFormat::tman_w4a16());
        assert!(llm > cpu);
        assert!(llm / tman > 3.0, "llm.npu/T-MAN decode {}", llm / tman);
    }

    #[test]
    fn tman_prefill_up_to_30x_over_cpu() {
        // §6.2 mpGEMM: "T-MAN delivers a speedup of up to 30x over CPU-only
        // frameworks like llama.cpp and T-MAC".
        let s = soc();
        let fmt = QuantFormat::tman_w4afp16();
        let tman = tman_gemm_latency_us(&s.npu, 128, 4096, 4096, fmt);
        let cpu = cpu_gemm(&s, 128, 4096, 4096, fmt).sequential_us();
        let ratio = cpu / tman;
        assert!(ratio > 8.0 && ratio < 40.0, "prefill CPU/T-MAN {ratio} (paper: up to 30x)");
    }

    #[test]
    fn tman_prefill_comparable_to_qnn_fp16() {
        // §6.2: "comparable to QNN's native W_FP16A_FP16 kernel".
        let s = soc();
        let tman = tman_gemm_latency_us(&s.npu, 128, 4096, 4096, QuantFormat::tman_w4afp16());
        let qnn = qnn_latency_us(&qnn_gemm(&s, 128, 4096, 4096, QuantFormat::qnn_fp16()));
        let ratio = tman / qnn;
        assert!(ratio < 1.5 && ratio > 0.4, "T-MAN/QNN prefill ratio {ratio}");
    }

    #[test]
    fn tman_beats_llmnpu_on_small_prefill_shapes() {
        // §6.2: "considerably faster than llm.npu on smaller matrix shapes
        // (e.g., 2560x2560x128), as it avoids the NPU-CPU synchronization".
        let s = soc();
        let tman = tman_gemm_latency_us(&s.npu, 128, 2560, 2560, QuantFormat::tman_w2a16());
        let llm = llmnpu_gemm(&s, 128, 2560, 2560).sequential_us();
        assert!(llm / tman > 1.2, "llm.npu/T-MAN small-shape prefill {}", llm / tman);
    }

    #[test]
    fn llmnpu_double_copy_oom_on_12gb() {
        // §6.3: llm.npu OOMs 8B models on the 12 GB OnePlus 13T.
        // 8B params: INT8 copy (8 GB) + INT4 copy (4 GB) > 12 GB with
        // activations; T-MAN's single INT4 copy fits easily.
        let params: usize = 8_000_000_000;
        let llm_bytes = params + params / 2;
        let tman_bytes = QuantFormat::tman_w4a16().weight_footprint(params / 4096, 4096);
        let dram = SocConfig::oneplus13t().dram_bytes;
        assert!(llm_bytes > dram * 9 / 10, "llm.npu resident {llm_bytes} should exhaust 12GB");
        assert!(tman_bytes < dram / 2);
    }

    #[test]
    fn format_support_matrix() {
        assert!(Framework::TMan.supports(QuantFormat::tman_w4a16()));
        assert!(Framework::TMan.supports(QuantFormat::bitnet()));
        assert!(!Framework::Qnn.supports(QuantFormat::tman_w4a16())); // per-block
        assert!(Framework::Qnn.supports(QuantFormat::qnn_w4a16()));
        assert!(Framework::BitnetCpp.supports(QuantFormat::bitnet()));
        assert!(!Framework::BitnetCpp.supports(QuantFormat::tman_w4a16()));
        assert!(Framework::LlamaCpp.supports(QuantFormat::tman_w4a16()));
    }

    #[test]
    fn placements() {
        assert_eq!(Framework::TMan.placement(Phase::Decode), Placement::NpuOnly);
        assert_eq!(Framework::LlamaCpp.placement(Phase::Decode), Placement::CpuOnly);
        assert_eq!(Framework::LlmNpu.placement(Phase::Prefill), Placement::Hybrid);
    }
}
