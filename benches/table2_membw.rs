//! Table 2: memory-bandwidth microbenchmark — vectorized load vs l2fetch vs
//! DMA at 1 and 4 HVX threads (64 MB stream on the simulated SD8 Gen 3).
use tman::bench::{banner, Table};
use tman::npu::config::NpuConfig;
use tman::npu::memory;

fn main() {
    let cfg = NpuConfig::sd8gen3();
    banner("Table 2 — memory bandwidth microbenchmark (OnePlus 12 model)");
    let mut t = Table::new(&["method", "BW (1 thread)", "BW (4 threads)"]);
    let rows = memory::table2(&cfg, 64 << 20);
    for m in [memory::LoadMethod::VectorizedLoad, memory::LoadMethod::L2Fetch, memory::LoadMethod::Dma] {
        let one = rows.iter().find(|r| r.method == m && r.threads == 1).unwrap().gbps;
        let four = rows.iter().find(|r| r.method == m && r.threads == 4).unwrap().gbps;
        t.row(&[m.name().into(), format!("{one:.0} GB/s"), format!("{four:.0} GB/s")]);
    }
    t.print();
    println!("\npaper Table 2: vectorized 5/20, l2fetch 26/32, DMA 59/59 GB/s");
    println!("conclusion: weights over DMA; scalar-side data over l2fetch (§5)");
}
