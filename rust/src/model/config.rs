//! Model architecture configurations.
//!
//! Two roles: (1) runnable small transformers (Llama-family architecture:
//! RMSNorm, RoPE, GQA, SwiGLU) for the end-to-end engine and the Table 4
//! accuracy experiment; (2) *shape descriptors* of the paper's evaluation
//! models (Qwen3-8B, Llama-3.1-8B, BitNet-2B) whose projection matrices
//! drive the kernel-level benchmarks (Figs. 12–13) without materializing
//! 8B parameters.

/// Architecture hyperparameters (Llama-family decoder-only transformer).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    /// Head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// KV projection output width (GQA).
    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.d_head()
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let emb = self.vocab * self.d_model;
        let attn = self.d_model * self.d_model  // q
            + 2 * self.d_model * self.d_kv()    // k, v
            + self.d_model * self.d_model; // o
        let mlp = 3 * self.d_model * self.d_ff; // gate, up, down
        let norms = self.n_layers * 2 * self.d_model + self.d_model;
        emb + self.n_layers * (attn + mlp) + norms + self.vocab * self.d_model
    }

    /// Byte-level test model: fast enough for unit tests.
    pub fn tiny() -> Self {
        Self {
            name: "tiny-test",
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 128,
            max_seq: 256,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// The byte-level model trained on the embedded corpus (~3M params) —
    /// the workload of the Table 4 PPL experiment and the e2e examples.
    pub fn small() -> Self {
        Self {
            name: "tman-small-3m",
            vocab: 256,
            d_model: 192,
            n_layers: 6,
            n_heads: 6,
            n_kv_heads: 2,
            d_ff: 512,
            max_seq: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// A ~100M-class config for scale tests of the serving stack (random
    /// weights; exercises memory/tiling paths, not accuracy).
    pub fn base_100m() -> Self {
        Self {
            name: "tman-base-100m",
            vocab: 4096,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_kv_heads: 4,
            d_ff: 2048,
            max_seq: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }
}

/// Shape descriptor of one projection (weight matrix is (m, k) = (out, in)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProjShape {
    pub name: &'static str,
    pub m: usize,
    pub k: usize,
}

/// Evaluation-model shape sets (§6.1–6.2). These are the mpGEMV/mpGEMM
/// kernel shapes of Figs. 12–13.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalModel {
    Qwen3_8B,
    Llama31_8B,
    BitNet2B,
}

impl EvalModel {
    pub fn name(self) -> &'static str {
        match self {
            EvalModel::Qwen3_8B => "Qwen3-8B",
            EvalModel::Llama31_8B => "Llama-3.1-8B",
            EvalModel::BitNet2B => "BitNet-2B",
        }
    }

    /// Projection shapes (m = output channels, k = input channels).
    pub fn shapes(self) -> Vec<ProjShape> {
        match self {
            // Llama-3.1-8B: d=4096, d_ff=14336, kv 1024.
            EvalModel::Llama31_8B => vec![
                ProjShape { name: "qkv", m: 6144, k: 4096 },
                ProjShape { name: "o", m: 4096, k: 4096 },
                ProjShape { name: "gate/up", m: 14336, k: 4096 },
                ProjShape { name: "down", m: 4096, k: 14336 },
            ],
            // Qwen3-8B: d=4096, d_ff=12288, kv 1024.
            EvalModel::Qwen3_8B => vec![
                ProjShape { name: "qkv", m: 6144, k: 4096 },
                ProjShape { name: "o", m: 4096, k: 4096 },
                ProjShape { name: "gate/up", m: 12288, k: 4096 },
                ProjShape { name: "down", m: 4096, k: 12288 },
            ],
            // BitNet-2B: d=2560, d_ff=6912 (paper quotes shapes
            // {2560,6912}x{2560,6912}).
            EvalModel::BitNet2B => vec![
                ProjShape { name: "attn", m: 2560, k: 2560 },
                ProjShape { name: "gate/up", m: 6912, k: 2560 },
                ProjShape { name: "down", m: 2560, k: 6912 },
            ],
        }
    }

    /// Full per-layer projection multiset (m, k) — unlike [`shapes`], this
    /// counts gate AND up separately; it is the unit of end-to-end
    /// extrapolation (decode streams every one of these per layer).
    pub fn layer_projections(self) -> Vec<(usize, usize)> {
        match self {
            EvalModel::Llama31_8B => {
                vec![(6144, 4096), (4096, 4096), (14336, 4096), (14336, 4096), (4096, 14336)]
            }
            EvalModel::Qwen3_8B => {
                vec![(6144, 4096), (4096, 4096), (12288, 4096), (12288, 4096), (4096, 12288)]
            }
            EvalModel::BitNet2B => {
                vec![(7680, 2560), (2560, 2560), (6912, 2560), (6912, 2560), (2560, 6912)]
            }
        }
    }

    /// LM head (vocab, d_model).
    pub fn lm_head_shape(self) -> (usize, usize) {
        match self {
            EvalModel::Llama31_8B => (128_256, 4096),
            EvalModel::Qwen3_8B => (151_936, 4096),
            EvalModel::BitNet2B => (128_256, 2560),
        }
    }

    /// Number of decoder layers (for end-to-end extrapolation).
    pub fn n_layers(self) -> usize {
        match self {
            EvalModel::Qwen3_8B => 36,
            EvalModel::Llama31_8B => 32,
            EvalModel::BitNet2B => 30,
        }
    }

    /// d_model (attention path width).
    pub fn d_model(self) -> usize {
        match self {
            EvalModel::BitNet2B => 2560,
            _ => 4096,
        }
    }

    pub fn all() -> [EvalModel; 3] {
        [EvalModel::Qwen3_8B, EvalModel::Llama31_8B, EvalModel::BitNet2B]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dims_divide() {
        for c in [ModelConfig::tiny(), ModelConfig::small(), ModelConfig::base_100m()] {
            assert_eq!(c.d_model % c.n_heads, 0, "{}", c.name);
            assert_eq!(c.n_heads % c.n_kv_heads, 0, "{}", c.name);
        }
    }

    #[test]
    fn param_counts_in_expected_range() {
        let small = ModelConfig::small().param_count();
        assert!(small > 2_000_000 && small < 6_000_000, "small {small}");
        let base = ModelConfig::base_100m().param_count();
        assert!(base > 80_000_000 && base < 150_000_000, "base {base}");
    }

    #[test]
    fn eval_shapes_match_paper() {
        let bn = EvalModel::BitNet2B.shapes();
        assert!(bn.iter().any(|s| s.m == 6912 && s.k == 2560));
        assert!(bn.iter().any(|s| s.m == 2560 && s.k == 6912));
        let ll = EvalModel::Llama31_8B.shapes();
        assert!(ll.iter().any(|s| s.m == 14336));
    }
}
