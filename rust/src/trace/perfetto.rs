//! Chrome-trace-event / Perfetto JSON export and standalone replay.
//!
//! [`export`] renders a [`Tracer`] as Chrome trace-event JSON (the
//! format `ui.perfetto.dev` and `chrome://tracing` load directly): one
//! track per replica × rail (`npu` / `cpu` kernel spans, `mem` for tier
//! DMA and KV instants, `lifecycle` for request edges, `router` for
//! fleet decisions) plus per-request async spans from submit to the
//! terminal edge. Timestamps are the sim clock in µs — the unit the
//! trace-event format expects.
//!
//! The export is *lossless* for the auditor: every event's exact
//! payload rides in `args` (floats via Rust's shortest-roundtrip
//! `Display`, 64-bit keys as strings), and the file embeds the
//! export-time [`audit`](super::audit) summary under `otherData`,
//! schema-version stamped. [`check`] replays a saved file: full JSON
//! syntax validation, per-track timestamp monotonicity, event
//! reconstruction, a fresh audit, and a field-by-field cross-check
//! against the embedded summary — so `tman trace-check` can vouch for
//! a trace long after the run that produced it is gone.

use super::audit::{audit, AuditReport};
use super::{
    peak_inflight, restore_stall_us, KvEvent, Recorded, RejectReason, ShedReason, TraceEvent,
    Tracer, TRACE_SCHEMA_VERSION,
};
use crate::coordinator::engine::Processor;
use anyhow::{bail, ensure, Context, Result};
use std::fmt::Write as _;

/// Thread (track) ids within one replica's process group.
const TID_NPU: u64 = 1;
const TID_CPU: u64 = 2;
const TID_MEM: u64 = 3;
const TID_LIFE: u64 = 4;
const TID_ROUTER: u64 = 5;

fn tid_of(p: Processor) -> u64 {
    match p {
        Processor::Npu => TID_NPU,
        Processor::Cpu => TID_CPU,
    }
}

fn reject_name(r: RejectReason) -> &'static str {
    match r {
        RejectReason::DeadlineOnArrival => "deadline",
        RejectReason::ClassCap => "class-cap",
        RejectReason::QueueFull => "queue-full",
    }
}

fn reject_of(name: &str) -> Option<RejectReason> {
    match name {
        "deadline" => Some(RejectReason::DeadlineOnArrival),
        "class-cap" => Some(RejectReason::ClassCap),
        "queue-full" => Some(RejectReason::QueueFull),
        _ => None,
    }
}

fn shed_name(r: ShedReason) -> &'static str {
    match r {
        ShedReason::Displaced => "displaced",
        ShedReason::DeadlineQueued => "deadline-queued",
        ShedReason::DeadlineRunning => "deadline-running",
    }
}

fn shed_of(name: &str) -> Option<ShedReason> {
    match name {
        "displaced" => Some(ShedReason::Displaced),
        "deadline-queued" => Some(ShedReason::DeadlineQueued),
        "deadline-running" => Some(ShedReason::DeadlineRunning),
        _ => None,
    }
}

/// One complete ("X") kernel span line.
fn span_line(out: &mut String, pid: usize, tid: u64, name: &str, ts: f64, dur: f64, args: &str) {
    let _ = write!(
        out,
        "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts},\"dur\":{dur},\
         \"name\":\"{name}\",\"cat\":\"kernel\",\"args\":{{{args}}}}}"
    );
}

/// One instant ("i") line.
fn instant_line(out: &mut String, pid: usize, tid: u64, name: &str, ts: f64, args: &str) {
    let _ = write!(
        out,
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts},\"s\":\"t\",\
         \"name\":\"{name}\",\"cat\":\"event\",\"args\":{{{args}}}}}"
    );
}

/// One async begin/end line pairing a request's lifetime span.
fn async_line(out: &mut String, ph: char, pid: usize, id: u64, ts: f64) {
    let _ = write!(
        out,
        "{{\"ph\":\"{ph}\",\"pid\":{pid},\"tid\":{TID_LIFE},\"ts\":{ts},\
         \"id\":\"{id}\",\"name\":\"request\",\"cat\":\"request\",\"args\":{{}}}}"
    );
}

fn opt_num(key: &str, v: Option<f64>) -> String {
    match v {
        Some(x) => format!(",\"{key}\":{x}"),
        None => String::new(),
    }
}

/// The embedded summary: the export-time audit flattened to string
/// values (Chrome's `otherData` convention), which [`check`] re-derives
/// and compares verbatim. Class stats pack into one
/// `prio:completed:generated:p50:p99:misses;…` string.
fn summary_pairs(rep: &AuditReport, events: usize) -> Vec<(String, String)> {
    let d = &rep.dispatch;
    let mut classes = String::new();
    for c in &rep.class_stats {
        let _ = write!(
            classes,
            "{}:{}:{}:{}:{}:{};",
            c.priority, c.completed, c.generated_tokens, c.ttft_p50_ms, c.ttft_p99_ms,
            c.deadline_misses
        );
    }
    vec![
        ("schema_version".into(), TRACE_SCHEMA_VERSION.to_string()),
        ("events".into(), events.to_string()),
        ("dropped".into(), rep.dropped.to_string()),
        ("makespan_us".into(), rep.makespan_us.to_string()),
        ("npu_us".into(), d.npu_us.to_string()),
        ("cpu_us".into(), d.cpu_us.to_string()),
        ("npu_j".into(), d.npu_j.to_string()),
        ("cpu_j".into(), d.cpu_j.to_string()),
        ("prefill_npu".into(), d.prefill_npu.to_string()),
        ("prefill_cpu".into(), d.prefill_cpu.to_string()),
        ("decode_npu".into(), d.decode_npu.to_string()),
        ("decode_cpu".into(), d.decode_cpu.to_string()),
        ("submitted".into(), rep.submitted.to_string()),
        ("rejected".into(), rep.rejected.to_string()),
        ("shed".into(), rep.shed.to_string()),
        ("completed".into(), rep.completed.to_string()),
        ("preemptions".into(), rep.preemptions.to_string()),
        ("resumed".into(), rep.resumed.to_string()),
        ("decode_evictions".into(), rep.decode_evictions.to_string()),
        ("decode_batches_executed".into(), rep.decode_batches_executed.to_string()),
        ("prefix_hits".into(), rep.prefix_hits.to_string()),
        ("prefix_hit_tokens".into(), rep.prefix_hit_tokens.to_string()),
        ("tier_spills".into(), rep.tier_spills.to_string()),
        ("tier_restores".into(), rep.tier_restores.to_string()),
        ("tier_restored_bytes".into(), rep.tier_restored_bytes.to_string()),
        ("tier_gc_reclaimed".into(), rep.tier_gc_reclaimed.to_string()),
        ("tier_restore_us".into(), rep.tier_restore_us.to_string()),
        ("ttft_p50_ms".into(), rep.ttft_p50_ms.to_string()),
        ("ttft_p99_ms".into(), rep.ttft_p99_ms.to_string()),
        ("util_npu".into(), rep.util_npu.to_string()),
        ("util_cpu".into(), rep.util_cpu.to_string()),
        ("peak_inflight".into(), rep.peak_inflight.to_string()),
        ("restore_stall_us".into(), rep.restore_stall_us.to_string()),
        ("classes".into(), classes),
    ]
}

/// Render one recorded event as a single trace-event line (plus, for
/// lifecycle edges, the async begin/end line that draws the request's
/// lifetime bar). Metadata lines are emitted separately by [`export`].
fn event_lines(lines: &mut Vec<String>, r: &Recorded) {
    let pid = r.replica;
    let mut s = String::new();
    match &r.ev {
        TraceEvent::Submit {
            id,
            priority,
            arrival_us,
            at_us,
            prompt_tokens,
            max_new_tokens,
            deadline_at_us,
        } => {
            instant_line(
                &mut s,
                pid,
                TID_LIFE,
                "submit",
                *at_us,
                &format!(
                    "\"id\":{id},\"prio\":{priority},\"arrival\":{arrival_us},\
                     \"prompt\":{prompt_tokens},\"max_new\":{max_new_tokens}{}",
                    opt_num("deadline", *deadline_at_us)
                ),
            );
            lines.push(std::mem::take(&mut s));
            async_line(&mut s, 'b', pid, *id, *at_us);
        }
        TraceEvent::Reject { id, priority, at_us, reason } => {
            instant_line(
                &mut s,
                pid,
                TID_LIFE,
                "reject",
                *at_us,
                &format!("\"id\":{id},\"prio\":{priority},\"reason\":\"{}\"", reject_name(*reason)),
            );
            lines.push(std::mem::take(&mut s));
            async_line(&mut s, 'e', pid, *id, *at_us);
        }
        TraceEvent::Shed { id, priority, at_us, reason } => {
            instant_line(
                &mut s,
                pid,
                TID_LIFE,
                "shed",
                *at_us,
                &format!("\"id\":{id},\"prio\":{priority},\"reason\":\"{}\"", shed_name(*reason)),
            );
            lines.push(std::mem::take(&mut s));
            async_line(&mut s, 'e', pid, *id, *at_us);
        }
        TraceEvent::PrefillSpan {
            id,
            sched_start,
            sched_len,
            computed,
            begin_us,
            end_us,
            processor,
            us,
            energy_j,
            npu_quote_us,
            cpu_quote_us,
            inflight,
            queued_launches,
            saved_us,
        } => span_line(
            &mut s,
            pid,
            tid_of(*processor),
            "prefill",
            *begin_us,
            end_us - begin_us,
            &format!(
                "\"id\":{id},\"start\":{sched_start},\"sched_len\":{sched_len},\
                 \"computed\":{computed},\"us\":{us},\"j\":{energy_j},\
                 \"npu_q\":{npu_quote_us},\"cpu_q\":{cpu_quote_us},\
                 \"inflight\":{inflight},\"queued\":{queued_launches},\
                 \"saved_us\":{saved_us},\"end_ts\":{end_us}"
            ),
        ),
        TraceEvent::CachedSlice { id, at_us, tokens, saved_us } => instant_line(
            &mut s,
            pid,
            TID_LIFE,
            "cached-slice",
            *at_us,
            &format!("\"id\":{id},\"tokens\":{tokens},\"saved_us\":{saved_us}"),
        ),
        TraceEvent::RestoreSpan { id, begin_us, end_us, us, energy_j } => span_line(
            &mut s,
            pid,
            TID_MEM,
            "tier-restore",
            *begin_us,
            end_us - begin_us,
            &format!("\"id\":{id},\"us\":{us},\"j\":{energy_j},\"end_ts\":{end_us}"),
        ),
        TraceEvent::DecodeSpan {
            lanes,
            begin_us,
            end_us,
            processor,
            us,
            energy_j,
            npu_quote_us,
            cpu_quote_us,
            inflight,
            queued_launches,
        } => span_line(
            &mut s,
            pid,
            tid_of(*processor),
            "decode",
            *begin_us,
            end_us - begin_us,
            &format!(
                "\"lanes\":{lanes},\"us\":{us},\"j\":{energy_j},\
                 \"npu_q\":{npu_quote_us},\"cpu_q\":{cpu_quote_us},\
                 \"inflight\":{inflight},\"queued\":{queued_launches},\"end_ts\":{end_us}"
            ),
        ),
        TraceEvent::FirstToken { id, at_us } => {
            instant_line(&mut s, pid, TID_LIFE, "first-token", *at_us, &format!("\"id\":{id}"))
        }
        TraceEvent::Preempt { id, at_us } => {
            instant_line(&mut s, pid, TID_LIFE, "preempt", *at_us, &format!("\"id\":{id}"))
        }
        TraceEvent::Resume { id, at_us } => {
            instant_line(&mut s, pid, TID_LIFE, "resume", *at_us, &format!("\"id\":{id}"))
        }
        TraceEvent::Publish { id, at_us, blocks } => instant_line(
            &mut s,
            pid,
            TID_LIFE,
            "publish",
            *at_us,
            &format!("\"id\":{id},\"blocks\":{blocks}"),
        ),
        TraceEvent::Evict { id, at_us } => {
            instant_line(&mut s, pid, TID_LIFE, "evict", *at_us, &format!("\"id\":{id}"))
        }
        TraceEvent::Finish {
            id,
            priority,
            at_us,
            generated_tokens,
            ttft_us,
            queue_wait_us,
            energy_prefill_j,
            energy_decode_j,
            ttft_slo_us,
        } => {
            instant_line(
                &mut s,
                pid,
                TID_LIFE,
                "finish",
                *at_us,
                &format!(
                    "\"id\":{id},\"prio\":{priority},\"gen\":{generated_tokens},\
                     \"ttft_us\":{ttft_us},\"wait_us\":{queue_wait_us},\
                     \"pj\":{energy_prefill_j},\"dj\":{energy_decode_j}{}",
                    opt_num("slo", *ttft_slo_us)
                ),
            );
            lines.push(std::mem::take(&mut s));
            async_line(&mut s, 'e', pid, *id, *at_us);
        }
        TraceEvent::Kv { at_us, ev } => match ev {
            KvEvent::PrefixHit { id, tokens } => instant_line(
                &mut s,
                pid,
                TID_MEM,
                "kv-hit",
                *at_us,
                &format!("\"id\":{id},\"tokens\":{tokens}"),
            ),
            KvEvent::Cow { block } => {
                instant_line(&mut s, pid, TID_MEM, "kv-cow", *at_us, &format!("\"block\":{block}"))
            }
            KvEvent::Spill { key, bytes } => instant_line(
                &mut s,
                pid,
                TID_MEM,
                "kv-spill",
                *at_us,
                &format!("\"key\":\"{key}\",\"bytes\":{bytes}"),
            ),
            KvEvent::Restore { key, bytes } => instant_line(
                &mut s,
                pid,
                TID_MEM,
                "kv-restore",
                *at_us,
                &format!("\"key\":\"{key}\",\"bytes\":{bytes}"),
            ),
            KvEvent::Gc { reclaimed } => instant_line(
                &mut s,
                pid,
                TID_MEM,
                "kv-gc",
                *at_us,
                &format!("\"reclaimed\":{reclaimed}"),
            ),
        },
        TraceEvent::Route { id, replica, at_us, load_us, saved_us, sticky_us } => instant_line(
            &mut s,
            *replica,
            TID_ROUTER,
            "route",
            *at_us,
            &format!(
                "\"id\":{id},\"load_us\":{load_us},\"saved_us\":{saved_us},\
                 \"sticky_us\":{sticky_us}"
            ),
        ),
        TraceEvent::Steal { id, from, to, at_us } => instant_line(
            &mut s,
            *to,
            TID_ROUTER,
            "steal",
            *at_us,
            &format!("\"id\":{id},\"from\":{from},\"to\":{to}"),
        ),
        TraceEvent::RouterReject { id, at_us } => {
            instant_line(&mut s, pid, TID_ROUTER, "router-reject", *at_us, &format!("\"id\":{id}"))
        }
    }
    lines.push(s);
}

/// Export a tracer as Chrome-trace / Perfetto JSON. One event per line
/// inside `traceEvents`, summary embedded under `otherData`.
pub fn export(t: &Tracer) -> String {
    let mut rep = audit(t.events(), t.dropped());
    rep.peak_inflight = peak_inflight(t);
    rep.restore_stall_us = restore_stall_us(t);
    let mut lines: Vec<String> = Vec::with_capacity(t.len() + 16);
    // Name the process/track grid up front so Perfetto renders labeled
    // rails even for replicas whose first event comes late.
    let mut replicas: Vec<usize> = t.events().iter().map(|r| r.replica).collect();
    replicas.sort_unstable();
    replicas.dedup();
    for &pid in &replicas {
        lines.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
             \"args\":{{\"name\":\"replica {pid}\"}}}}"
        ));
        for (tid, name) in [
            (TID_NPU, "npu"),
            (TID_CPU, "cpu"),
            (TID_MEM, "mem"),
            (TID_LIFE, "lifecycle"),
            (TID_ROUTER, "router"),
        ] {
            lines.push(format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ));
        }
    }
    for r in t.events() {
        event_lines(&mut lines, r);
    }
    let mut out = String::with_capacity(lines.iter().map(|l| l.len() + 2).sum::<usize>() + 2048);
    out.push_str("{\n\"displayTimeUnit\": \"ms\",\n\"otherData\": {");
    for (i, (k, v)) in summary_pairs(&rep, t.len()).iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{k}\":\"{v}\"");
    }
    out.push_str("},\n\"traceEvents\": [\n");
    for (i, l) in lines.iter().enumerate() {
        out.push_str(l);
        if i + 1 < lines.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

// ---- minimal JSON syntax validator (no deps, recursion depth is the
// document's nesting depth — bounded at 4 for our own exports) ----

struct Json<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Json<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek() == Some(c), "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<()> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, s: &str) -> Result<()> {
        ensure!(self.b[self.i..].starts_with(s.as_bytes()), "bad literal at byte {}", self.i);
        self.i += s.len();
        Ok(())
    }

    fn string(&mut self) -> Result<()> {
        self.eat(b'"')?;
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => {
                    ensure!(self.peek().is_some(), "truncated escape at byte {}", self.i);
                    self.i += 1;
                }
                _ => {}
            }
        }
        bail!("unterminated string");
    }

    fn number(&mut self) -> Result<()> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        ensure!(self.i > start, "empty number at byte {start}");
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .with_context(|| format!("malformed number at byte {start}"))?;
        Ok(())
    }

    fn object(&mut self) -> Result<()> {
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(b':')?;
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<()> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }
}

/// Full-syntax JSON validation of `text` (value + trailing whitespace).
pub fn validate_json(text: &str) -> Result<()> {
    let mut p = Json { b: text.as_bytes(), i: 0 };
    p.value()?;
    p.ws();
    ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
    Ok(())
}

// ---- line-field extraction for the replay parser (exports write one
// event per line with fixed, non-escaped key names) ----

fn num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = &line[at..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let at = line.find(&pat)? + pat.len();
    let rest = &line[at..];
    Some(rest[..rest.find('"')?].to_string())
}

fn u_field(line: &str, key: &str) -> Option<usize> {
    num_field(line, key).map(|x| x as usize)
}

fn id_field(line: &str, key: &str) -> Option<u64> {
    num_field(line, key).map(|x| x as u64)
}

/// What [`check`] verified, for the CLI to print.
#[derive(Debug)]
pub struct CheckReport {
    pub events: usize,
    pub tracks: usize,
    pub report: AuditReport,
}

fn rebuild_event(line: &str, name: &str, tid: u64, ts: f64) -> Result<TraceEvent> {
    let want = |k: &str| -> Result<f64> {
        num_field(line, k).with_context(|| format!("event '{name}' missing arg '{k}'"))
    };
    let wantu = |k: &str| -> Result<usize> { Ok(want(k)? as usize) };
    let wantid = |k: &str| -> Result<u64> { Ok(want(k)? as u64) };
    let wantkey = |k: &str| -> Result<u64> {
        str_field(line, k)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("event '{name}' missing key arg '{k}'"))
    };
    let proc_of = |tid: u64| -> Result<Processor> {
        match tid {
            TID_NPU => Ok(Processor::Npu),
            TID_CPU => Ok(Processor::Cpu),
            other => bail!("kernel span on non-rail track tid={other}"),
        }
    };
    Ok(match name {
        "submit" => TraceEvent::Submit {
            id: wantid("id")?,
            priority: want("prio")? as u8,
            arrival_us: want("arrival")?,
            at_us: ts,
            prompt_tokens: wantu("prompt")?,
            max_new_tokens: wantu("max_new")?,
            deadline_at_us: num_field(line, "deadline"),
        },
        "reject" => TraceEvent::Reject {
            id: wantid("id")?,
            priority: want("prio")? as u8,
            at_us: ts,
            reason: str_field(line, "reason")
                .as_deref()
                .and_then(reject_of)
                .context("bad reject reason")?,
        },
        "shed" => TraceEvent::Shed {
            id: wantid("id")?,
            priority: want("prio")? as u8,
            at_us: ts,
            reason: str_field(line, "reason")
                .as_deref()
                .and_then(shed_of)
                .context("bad shed reason")?,
        },
        "prefill" => TraceEvent::PrefillSpan {
            id: wantid("id")?,
            sched_start: wantu("start")?,
            sched_len: wantu("sched_len")?,
            computed: wantu("computed")?,
            begin_us: ts,
            end_us: want("end_ts")?,
            processor: proc_of(tid)?,
            us: want("us")?,
            energy_j: want("j")?,
            npu_quote_us: want("npu_q")?,
            cpu_quote_us: want("cpu_q")?,
            inflight: wantu("inflight")?,
            queued_launches: wantu("queued")?,
            saved_us: want("saved_us")?,
        },
        "cached-slice" => TraceEvent::CachedSlice {
            id: wantid("id")?,
            at_us: ts,
            tokens: wantu("tokens")?,
            saved_us: want("saved_us")?,
        },
        "tier-restore" => TraceEvent::RestoreSpan {
            id: wantid("id")?,
            begin_us: ts,
            end_us: want("end_ts")?,
            us: want("us")?,
            energy_j: want("j")?,
        },
        "decode" => TraceEvent::DecodeSpan {
            lanes: wantu("lanes")?,
            begin_us: ts,
            end_us: want("end_ts")?,
            processor: proc_of(tid)?,
            us: want("us")?,
            energy_j: want("j")?,
            npu_quote_us: want("npu_q")?,
            cpu_quote_us: want("cpu_q")?,
            inflight: wantu("inflight")?,
            queued_launches: wantu("queued")?,
        },
        "first-token" => TraceEvent::FirstToken { id: wantid("id")?, at_us: ts },
        "preempt" => TraceEvent::Preempt { id: wantid("id")?, at_us: ts },
        "resume" => TraceEvent::Resume { id: wantid("id")?, at_us: ts },
        "publish" => {
            TraceEvent::Publish { id: wantid("id")?, at_us: ts, blocks: wantu("blocks")? }
        }
        "evict" => TraceEvent::Evict { id: wantid("id")?, at_us: ts },
        "finish" => TraceEvent::Finish {
            id: wantid("id")?,
            priority: want("prio")? as u8,
            at_us: ts,
            generated_tokens: wantu("gen")?,
            ttft_us: want("ttft_us")?,
            queue_wait_us: want("wait_us")?,
            energy_prefill_j: want("pj")?,
            energy_decode_j: want("dj")?,
            ttft_slo_us: num_field(line, "slo"),
        },
        "kv-hit" => TraceEvent::Kv {
            at_us: ts,
            ev: KvEvent::PrefixHit { id: wantid("id")?, tokens: wantu("tokens")? },
        },
        "kv-cow" => TraceEvent::Kv { at_us: ts, ev: KvEvent::Cow { block: wantu("block")? } },
        "kv-spill" => TraceEvent::Kv {
            at_us: ts,
            ev: KvEvent::Spill { key: wantkey("key")?, bytes: wantu("bytes")? },
        },
        "kv-restore" => TraceEvent::Kv {
            at_us: ts,
            ev: KvEvent::Restore { key: wantkey("key")?, bytes: wantu("bytes")? },
        },
        "kv-gc" => TraceEvent::Kv { at_us: ts, ev: KvEvent::Gc { reclaimed: wantu("reclaimed")? } },
        "route" => TraceEvent::Route {
            id: wantid("id")?,
            replica: 0, // re-tagged from pid by the caller
            at_us: ts,
            load_us: want("load_us")?,
            saved_us: want("saved_us")?,
            sticky_us: want("sticky_us")?,
        },
        "steal" => TraceEvent::Steal {
            id: wantid("id")?,
            from: wantu("from")?,
            to: wantu("to")?,
            at_us: ts,
        },
        "router-reject" => TraceEvent::RouterReject { id: wantid("id")?, at_us: ts },
        other => bail!("unknown event name '{other}' — schema drift?"),
    })
}

/// Replay a saved trace file: validate the JSON, check per-track
/// timestamp monotonicity, rebuild the event stream, audit it afresh,
/// and cross-check every summary figure the exporter embedded.
/// Schema-version gated: a stamp other than [`TRACE_SCHEMA_VERSION`]
/// (or a missing one) fails loudly instead of mis-deriving.
pub fn check(text: &str) -> Result<CheckReport> {
    validate_json(text).context("trace file is not valid JSON")?;
    let version = str_field(text, "schema_version")
        .context("trace has no otherData.schema_version stamp — not a tman trace?")?;
    ensure!(
        version == TRACE_SCHEMA_VERSION.to_string(),
        "trace schema version {version} != supported {TRACE_SCHEMA_VERSION} — \
         re-export with this build instead of replaying a stale file"
    );
    let body = text
        .split_once("\"traceEvents\": [")
        .context("no traceEvents array")?
        .1;
    let mut events: Vec<Recorded> = Vec::new();
    let mut last_ts: std::collections::HashMap<(usize, u64), f64> =
        std::collections::HashMap::new();
    let mut tracks = std::collections::HashSet::new();
    for line in body.lines() {
        let line = line.trim_end_matches(',');
        if !line.starts_with('{') {
            continue;
        }
        let ph = str_field(line, "ph").context("event without ph")?;
        if ph == "M" || ph == "b" || ph == "e" {
            continue; // metadata + async lifetime bars: presentation only
        }
        ensure!(ph == "X" || ph == "i", "unexpected event phase '{ph}'");
        let pid = u_field(line, "pid").context("event without pid")?;
        let tid = id_field(line, "tid").context("event without tid")?;
        let ts = num_field(line, "ts").context("event without ts")?;
        let name = str_field(line, "name").context("event without name")?;
        tracks.insert((pid, tid));
        if let Some(&prev) = last_ts.get(&(pid, tid)) {
            ensure!(
                ts >= prev,
                "track (replica {pid}, tid {tid}): timestamp {ts} < predecessor {prev} — \
                 non-monotone trace"
            );
        }
        last_ts.insert((pid, tid), ts);
        let mut ev = rebuild_event(line, &name, tid, ts)
            .with_context(|| format!("rebuilding '{name}' from: {line}"))?;
        if let TraceEvent::Route { replica, .. } = &mut ev {
            *replica = pid;
        }
        events.push(Recorded { replica: pid, ev });
    }
    let declared: usize = str_field(text, "events")
        .and_then(|s| s.parse().ok())
        .context("otherData.events missing")?;
    ensure!(
        declared == events.len(),
        "otherData.events says {declared} but {} event(s) parsed",
        events.len()
    );
    let dropped = str_field(text, "dropped")
        .and_then(|s| s.parse().ok())
        .context("otherData.dropped missing")?;
    let mut rep = audit(events.iter(), dropped);
    // Derived metrics need the stream, not a tracer; recompute inline.
    let mut t = Tracer::bounded(events.len().max(1));
    for r in &events {
        t.record_at(r.replica, r.ev.clone());
    }
    rep.peak_inflight = peak_inflight(&t);
    rep.restore_stall_us = restore_stall_us(&t);
    // Cross-check: re-render the summary from the replayed audit and
    // compare each field verbatim against what the exporter embedded
    // (float Display round-trips, so string equality is bit equality).
    for (k, v) in summary_pairs(&rep, events.len()) {
        let embedded = str_field(text, &k)
            .with_context(|| format!("otherData.{k} missing from trace"))?;
        ensure!(
            embedded == v,
            "replayed audit diverges from embedded summary at '{k}': \
             file says {embedded}, replay derives {v}"
        );
    }
    Ok(CheckReport { events: events.len(), tracks: tracks.len(), report: rep })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validator_accepts_and_rejects() {
        assert!(validate_json("{\"a\": [1, 2.5, -3e-2], \"b\": {\"c\": \"x\"}}").is_ok());
        assert!(validate_json("[true, false, null]").is_ok());
        assert!(validate_json("{\"a\": }").is_err());
        assert!(validate_json("{\"a\": 1} trailing").is_err());
        assert!(validate_json("{\"a\": 1,}").is_err());
    }

    #[test]
    fn export_roundtrips_through_check() {
        let mut t = Tracer::bounded(64);
        t.record(TraceEvent::Submit {
            id: 1,
            priority: 0,
            arrival_us: 0.0,
            at_us: 0.0,
            prompt_tokens: 8,
            max_new_tokens: 4,
            deadline_at_us: Some(1500.0),
        });
        t.record(TraceEvent::PrefillSpan {
            id: 1,
            sched_start: 0,
            sched_len: 8,
            computed: 8,
            begin_us: 0.0,
            end_us: 103.25,
            processor: Processor::Npu,
            us: 103.25,
            energy_j: 0.001953125,
            npu_quote_us: 103.25,
            cpu_quote_us: 250.5,
            inflight: 1,
            queued_launches: 0,
            saved_us: 0.0,
        });
        t.record(TraceEvent::FirstToken { id: 1, at_us: 103.25 });
        t.record(TraceEvent::Finish {
            id: 1,
            priority: 0,
            at_us: 150.0,
            generated_tokens: 4,
            ttft_us: 103.25,
            queue_wait_us: 0.0,
            energy_prefill_j: 0.001953125,
            energy_decode_j: 0.0005,
            ttft_slo_us: None,
        });
        let json = export(&t);
        let rep = check(&json).expect("round trip");
        assert_eq!(rep.events, 4);
        assert_eq!(rep.report.completed, 1);
        assert_eq!(rep.report.submitted, 1);
        assert_eq!(rep.report.makespan_us.to_bits(), 150.0f64.to_bits());
    }

    #[test]
    fn check_rejects_wrong_schema_version() {
        let t = Tracer::bounded(4);
        let json = export(&t).replace("\"schema_version\":\"1\"", "\"schema_version\":\"0\"");
        let err = check(&json).unwrap_err().to_string();
        assert!(err.contains("schema version"), "got: {err}");
    }

    #[test]
    fn check_rejects_non_monotone_tracks() {
        let mut t = Tracer::bounded(8);
        t.record(TraceEvent::FirstToken { id: 1, at_us: 100.0 });
        t.record(TraceEvent::FirstToken { id: 2, at_us: 50.0 });
        let json = export(&t);
        let err = check(&json).unwrap_err().to_string();
        assert!(err.contains("non-monotone"), "got: {err}");
    }
}
