//! Fig. 13: mpGEMM (prefill, sequence length 128) comparison across shapes
//! and frameworks. BitNet weights dequantize to INT8 (HMX int8 path);
//! Qwen/Llama per-block weights dequantize to FP16.
use tman::bench::{banner, Table};
use tman::kernels::baselines;
use tman::kernels::dequant_gemm::tman_gemm_latency_us;
use tman::model::config::EvalModel;
use tman::npu::config::SocConfig;
use tman::quant::formats::QuantFormat;

fn main() {
    let n = 128;
    for soc in [SocConfig::oneplus12(), SocConfig::oneplus13t()] {
        banner(&format!("Fig. 13 — mpGEMM latency (us), N={n}, on {}", soc.name));
        let mut t = Table::new(&["model", "shape", "T-MAN", "QNN fp16", "llm.npu", "llama.cpp", "T-MAC"]);
        for model in EvalModel::all() {
            let fmt = if model == EvalModel::BitNet2B {
                QuantFormat::bitnet()
            } else {
                QuantFormat::tman_w4afp16()
            };
            for s in model.shapes() {
                let tman = tman_gemm_latency_us(&soc.npu, n, s.m, s.k, fmt);
                let qnn = baselines::qnn_latency_us(&baselines::qnn_gemm(&soc, n, s.m, s.k, QuantFormat::qnn_fp16()));
                let llm = baselines::llmnpu_gemm(&soc, n, s.m, s.k).sequential_us();
                let cpu = baselines::cpu_gemm(&soc, n, s.m, s.k, fmt).sequential_us();
                let tmac = baselines::cpu_gemm(&soc, n, s.m, s.k, fmt).sequential_us() * 0.9;
                t.row(&[
                    model.name().into(),
                    format!("{}x{}x{n}", s.m, s.k),
                    format!("{tman:.0}"),
                    format!("{qnn:.0}"),
                    format!("{llm:.0}"),
                    format!("{cpu:.0}"),
                    format!("{tmac:.0}"),
                ]);
            }
        }
        t.print();
    }
    println!("\npaper Fig. 13 shape checks: T-MAN ~ QNN-FP16; faster than llm.npu at small shapes");
    println!("(avoids NPU-CPU sync); up to 30x over CPU-only frameworks.");
}
