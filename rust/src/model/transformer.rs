//! Decoder-only transformer (Llama family: RMSNorm → GQA attention with
//! RoPE → SwiGLU MLP), in plain Rust f32.
//!
//! This is the *reference* model used for accuracy experiments (Table 4 PPL)
//! and as the numeric cross-check for the JAX/PJRT serving path. Every
//! linear projection goes through [`Linear`], which is either full-precision
//! or a **planned** quantized layer — a
//! [`UnifiedLayerPlan`](crate::kernels::plan::UnifiedLayerPlan) holding the
//! single bit-serial weight buffer, the two-level dequant tables, and the
//! unified tiling both on-device phases run under. Flipping a model between
//! FP32, per-block W4/W2 and per-channel W4 is a weight-transformation, not
//! an architecture change, exactly as on device; the host-side numerics of
//! a planned layer are byte-identical to the unpacked-codes representation
//! it replaced.

use crate::kernels::plan::UnifiedLayerPlan;
use crate::model::config::ModelConfig;
use crate::model::kv_cache::{KvCache, KvLanes, MonoLanes};
use crate::npu::config::NpuConfig;
use crate::quant::formats::{ActDtype, Granularity, WeightDtype};
use crate::quant::quantize;

/// Prefill chunk length a quantized layer is planned for when the caller
/// does not say otherwise (the paper's 128-token chunk).
pub const DEFAULT_PLAN_CHUNK: usize = 128;

/// A linear projection y = W·x: full-precision weights, or a planned
/// quantized layer (the unified weight artifact both phases execute).
#[derive(Debug, Clone)]
pub enum Linear {
    F32 { w: Vec<f32>, m: usize, k: usize },
    Planned(Box<UnifiedLayerPlan>),
}

impl Linear {
    pub fn out_dim(&self) -> usize {
        match self {
            Linear::F32 { m, .. } => *m,
            Linear::Planned(p) => p.out_dim(),
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            Linear::F32 { k, .. } => *k,
            Linear::Planned(p) => p.in_dim(),
        }
    }

    /// y = W·x (GEMV) — a one-lane call into the shared-pass implementation.
    pub fn forward(&self, x: &[f32], y: &mut [f32]) {
        self.forward_lanes(&[x], &mut [y]);
    }

    /// Batched GEMV: `ys[lane] = W·xs[lane]` for every lane, streaming each
    /// weight row **once** for the whole batch — the reference-numerics
    /// mirror of the batched LUT kernel's shared weight pass.
    pub fn forward_batch(&self, xs: &[Vec<f32>], ys: &mut [Vec<f32>]) {
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut yr: Vec<&mut [f32]> = ys.iter_mut().map(|v| v.as_mut_slice()).collect();
        self.forward_lanes(&xr, &mut yr);
    }

    /// The single GEMV implementation both entry points share (so solo and
    /// batched can never diverge numerically): each weight row is read —
    /// and, for quantized matrices, decoded — once, then applied to every
    /// lane in turn.
    fn forward_lanes(&self, xs: &[&[f32]], ys: &mut [&mut [f32]]) {
        assert_eq!(xs.len(), ys.len());
        match self {
            Linear::F32 { w, m, k } => {
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    assert_eq!(x.len(), *k);
                    assert_eq!(y.len(), *m);
                }
                for i in 0..*m {
                    let row = &w[i * k..(i + 1) * k];
                    for (x, y) in xs.iter().zip(ys.iter_mut()) {
                        let mut acc = 0.0f32;
                        for (a, b) in row.iter().zip(x.iter()) {
                            acc += a * b;
                        }
                        y[i] = acc;
                    }
                }
            }
            Linear::Planned(p) => {
                let (m, k) = (p.out_dim(), p.in_dim());
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    assert_eq!(x.len(), k);
                    assert_eq!(y.len(), m);
                }
                // Decode each quantized row once — through the plan's exact
                // reference dequantization — and apply it to every lane.
                let mut row = vec![0.0f32; k];
                for i in 0..m {
                    p.dequant_row_into(i, &mut row);
                    for (x, y) in xs.iter().zip(ys.iter_mut()) {
                        let mut acc = 0.0f32;
                        for (a, b) in row.iter().zip(x.iter()) {
                            acc += a * b;
                        }
                        y[i] = acc;
                    }
                }
            }
        }
    }

    /// Quantize an F32 linear into a planned layer targeting `cfg` with
    /// `chunk`-token prefill slices (no-op if already planned). One call =
    /// one tiling search + one table build + one bit-serial buffer.
    pub fn planned(
        &self,
        cfg: &NpuConfig,
        chunk: usize,
        dtype: WeightDtype,
        gran: Granularity,
        use_gptq: bool,
    ) -> Linear {
        match self {
            Linear::F32 { w, m, k } => {
                let q = if use_gptq {
                    quantize::gptq(w, *m, *k, dtype, gran)
                } else {
                    quantize::rtn(w, *m, *k, dtype, gran)
                };
                Linear::Planned(Box::new(UnifiedLayerPlan::from_qmatrix(
                    cfg,
                    &q,
                    ActDtype::Fp16,
                    chunk,
                )))
            }
            other => other.clone(),
        }
    }

    /// [`Linear::planned`] against the default deployment target
    /// (Snapdragon 8 Gen 3, [`DEFAULT_PLAN_CHUNK`]-token chunks) — the
    /// accuracy experiments only need the numerics, which do not depend on
    /// the planned tiling.
    pub fn quantized(&self, dtype: WeightDtype, gran: Granularity, use_gptq: bool) -> Linear {
        self.planned(&NpuConfig::sd8gen3(), DEFAULT_PLAN_CHUNK, dtype, gran, use_gptq)
    }
}

/// One decoder layer's weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub mlp_norm: Vec<f32>,
    pub w_gate: Linear,
    pub w_up: Linear,
    pub w_down: Linear,
}

/// Full model weights.
#[derive(Debug, Clone)]
pub struct Transformer {
    pub cfg: ModelConfig,
    /// Token embedding table (vocab, d_model) row-major.
    pub embed: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    /// LM head (vocab, d_model).
    pub lm_head: Linear,
}

pub fn rmsnorm(x: &[f32], g: &[f32], eps: f32, out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &w) in out.iter_mut().zip(x).zip(g) {
        *o = v * inv * w;
    }
}

/// Rotary position embedding applied in place to one head vector.
pub fn rope(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    for i in 0..d / 2 {
        let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[2 * i], x[2 * i + 1]);
        x[2 * i] = a * cos - b * sin;
        x[2 * i + 1] = a * sin + b * cos;
    }
}

fn softmax_inplace(x: &mut [f32]) {
    let mx = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

/// One position's causal attention over its cached prefix: scores against
/// every K row `t <= pos`, softmax, V-weighted sum — per head, with GQA
/// head-group sharing. This is the *single* implementation of the
/// attention math; the batched decode step and the planned chunk pass both
/// call it (through whichever [`KvLanes`] backs the lane — monolithic or
/// paged), so the execution paths cannot drift numerically.
fn attend(
    kv: &dyn KvLanes,
    lane: usize,
    layer: usize,
    pos: usize,
    q: &[f32],
    out: &mut [f32],
    cfg: &ModelConfig,
) {
    let dh = cfg.d_head();
    let groups = cfg.n_heads / cfg.n_kv_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    out.fill(0.0);
    for head in 0..cfg.n_heads {
        let kvh = head / groups;
        let qh = &q[head * dh..(head + 1) * dh];
        let mut scores = vec![0.0f32; pos + 1];
        for (t, s) in scores.iter_mut().enumerate() {
            let kt = kv.k(lane, layer, t, kvh, dh);
            *s = qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * scale;
        }
        softmax_inplace(&mut scores);
        let o = &mut out[head * dh..(head + 1) * dh];
        for (t, &s) in scores.iter().enumerate() {
            let vt = kv.v(lane, layer, t, kvh, dh);
            for (ov, &vv) in o.iter_mut().zip(vt) {
                *ov += s * vv;
            }
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl Transformer {
    /// Forward one token at position `pos`, updating `cache`; returns
    /// logits. A one-lane batch: the solo step *is*
    /// [`Transformer::forward_batch`] with a single lane, so the two paths
    /// cannot diverge numerically.
    pub fn forward_token(&self, token: usize, pos: usize, cache: &mut KvCache) -> Vec<f32> {
        self.forward_batch(&[(token, pos)], &mut [cache])
            .pop()
            .expect("one lane in, one logits vector out")
    }

    /// Forward one decode step for a *batch* of independent requests
    /// backed by monolithic caches — a thin wrapper over
    /// [`Transformer::forward_batch_lanes`].
    pub fn forward_batch(
        &self,
        steps: &[(usize, usize)],
        caches: &mut [&mut KvCache],
    ) -> Vec<Vec<f32>> {
        self.forward_batch_lanes(steps, &mut MonoLanes(caches))
    }

    /// Forward one decode step for a *batch* of independent requests:
    /// `steps[lane] = (token, pos)` against lane `lane` of `kv`. Every
    /// linear projection streams its weights once for the whole batch
    /// ([`Linear::forward_batch`] — the reference-numerics mirror of the
    /// batched LUT kernel's shared weight pass); attention and the
    /// element-wise ops run per lane against that lane's own KV storage,
    /// monolithic or paged. Each lane's logits are bit-identical to a solo
    /// [`Transformer::forward_token`] call.
    pub fn forward_batch_lanes(
        &self,
        steps: &[(usize, usize)],
        kv: &mut dyn KvLanes,
    ) -> Vec<Vec<f32>> {
        let c = &self.cfg;
        let lanes = steps.len();
        assert!(lanes > 0, "empty decode batch");
        assert_eq!(kv.lanes(), lanes, "one KV lane per batched request");
        let d = c.d_model;
        let dh = c.d_head();
        let dkv = c.d_kv();
        for &(token, pos) in steps {
            assert!(token < c.vocab, "token {token} out of vocab");
            assert!(pos < c.max_seq, "pos {pos} exceeds max_seq");
        }

        let mut h: Vec<Vec<f32>> =
            steps.iter().map(|&(t, _)| self.embed[t * d..(t + 1) * d].to_vec()).collect();
        let mut normed = vec![vec![0.0f32; d]; lanes];
        let mut q = vec![vec![0.0f32; d]; lanes];
        let mut k = vec![vec![0.0f32; dkv]; lanes];
        let mut v = vec![vec![0.0f32; dkv]; lanes];
        let mut attn_out = vec![vec![0.0f32; d]; lanes];
        let mut proj = vec![vec![0.0f32; d]; lanes];
        let mut gate = vec![vec![0.0f32; c.d_ff]; lanes];
        let mut up = vec![vec![0.0f32; c.d_ff]; lanes];
        let mut down = vec![vec![0.0f32; d]; lanes];

        for (li, layer) in self.layers.iter().enumerate() {
            // --- attention ---
            for lane in 0..lanes {
                rmsnorm(&h[lane], &layer.attn_norm, c.norm_eps, &mut normed[lane]);
            }
            // One pass over each projection's weights serves every lane.
            layer.wq.forward_batch(&normed, &mut q);
            layer.wk.forward_batch(&normed, &mut k);
            layer.wv.forward_batch(&normed, &mut v);
            for (lane, &(_, pos)) in steps.iter().enumerate() {
                for head in 0..c.n_heads {
                    rope(&mut q[lane][head * dh..(head + 1) * dh], pos, c.rope_theta);
                }
                for kvh in 0..c.n_kv_heads {
                    rope(&mut k[lane][kvh * dh..(kvh + 1) * dh], pos, c.rope_theta);
                }
                kv.append(lane, li, pos, &k[lane], &v[lane]);
                attend(kv, lane, li, pos, &q[lane], &mut attn_out[lane], c);
            }
            layer.wo.forward_batch(&attn_out, &mut proj);
            for lane in 0..lanes {
                for (hv, p) in h[lane].iter_mut().zip(&proj[lane]) {
                    *hv += p;
                }
            }

            // --- MLP ---
            for lane in 0..lanes {
                rmsnorm(&h[lane], &layer.mlp_norm, c.norm_eps, &mut normed[lane]);
            }
            layer.w_gate.forward_batch(&normed, &mut gate);
            layer.w_up.forward_batch(&normed, &mut up);
            for lane in 0..lanes {
                for (g, u) in gate[lane].iter_mut().zip(&up[lane]) {
                    *g = silu(*g) * u;
                }
            }
            layer.w_down.forward_batch(&gate, &mut down);
            for lane in 0..lanes {
                for (hv, dn) in h[lane].iter_mut().zip(&down[lane]) {
                    *hv += dn;
                }
            }
        }

        for lane in 0..lanes {
            let hc = h[lane].clone();
            rmsnorm(&hc, &self.final_norm, c.norm_eps, &mut h[lane]);
        }
        let mut logits = vec![vec![0.0f32; c.vocab]; lanes];
        self.lm_head.forward_batch(&h, &mut logits);
        logits
    }

    /// [`Transformer::forward_chunk_lanes`] against a single monolithic
    /// cache.
    pub fn forward_chunk(
        &self,
        tokens: &[usize],
        pos_base: usize,
        cache: &mut KvCache,
    ) -> Vec<f32> {
        let mut lanes: [&mut KvCache; 1] = [cache];
        self.forward_chunk_lanes(tokens, pos_base, &mut MonoLanes(&mut lanes))
    }

    /// Run one prefill chunk `tokens` at positions
    /// `pos_base .. pos_base + tokens.len()` against a single request's
    /// KV storage (lane 0 of `kv`) — the host-side mirror of the planned
    /// prefill GEMM. Every linear projection streams (and, for planned
    /// layers, decodes) its weights **once** for the whole chunk: the
    /// chunk positions form the (n × K) activation block of the matrix
    /// path and go through [`Linear::forward_batch`] together. K/V rows
    /// for all chunk positions land in the cache before attention, then
    /// each position attends over its own causal prefix — a prefix that
    /// may begin with *shared* blocks another request computed (the
    /// prefix-cache hit path) — so the logits at the last position are
    /// byte-identical to feeding the chunk through
    /// [`Transformer::forward_token`] one position at a time.
    pub fn forward_chunk_lanes(
        &self,
        tokens: &[usize],
        pos_base: usize,
        kv: &mut dyn KvLanes,
    ) -> Vec<f32> {
        assert_eq!(kv.lanes(), 1, "a prefill chunk runs against one request");
        let c = &self.cfg;
        let n = tokens.len();
        assert!(n > 0, "empty prefill chunk");
        let d = c.d_model;
        let dh = c.d_head();
        let dkv = c.d_kv();
        for (off, &token) in tokens.iter().enumerate() {
            assert!(token < c.vocab, "token {token} out of vocab");
            assert!(pos_base + off < c.max_seq, "pos {} exceeds max_seq", pos_base + off);
        }

        let mut h: Vec<Vec<f32>> =
            tokens.iter().map(|&t| self.embed[t * d..(t + 1) * d].to_vec()).collect();
        let mut normed = vec![vec![0.0f32; d]; n];
        let mut q = vec![vec![0.0f32; d]; n];
        let mut k = vec![vec![0.0f32; dkv]; n];
        let mut v = vec![vec![0.0f32; dkv]; n];
        let mut attn_out = vec![vec![0.0f32; d]; n];
        let mut proj = vec![vec![0.0f32; d]; n];
        let mut gate = vec![vec![0.0f32; c.d_ff]; n];
        let mut up = vec![vec![0.0f32; c.d_ff]; n];
        let mut down = vec![vec![0.0f32; d]; n];

        for (li, layer) in self.layers.iter().enumerate() {
            // --- attention ---
            for lane in 0..n {
                rmsnorm(&h[lane], &layer.attn_norm, c.norm_eps, &mut normed[lane]);
            }
            // One pass over each projection's weights serves the chunk.
            layer.wq.forward_batch(&normed, &mut q);
            layer.wk.forward_batch(&normed, &mut k);
            layer.wv.forward_batch(&normed, &mut v);
            // All K/V rows of the chunk are staged before any position
            // attends: position p only reads t <= p, and those rows do not
            // depend on any attention output within the layer.
            for lane in 0..n {
                let pos = pos_base + lane;
                for head in 0..c.n_heads {
                    rope(&mut q[lane][head * dh..(head + 1) * dh], pos, c.rope_theta);
                }
                for kvh in 0..c.n_kv_heads {
                    rope(&mut k[lane][kvh * dh..(kvh + 1) * dh], pos, c.rope_theta);
                }
                kv.append(0, li, pos, &k[lane], &v[lane]);
            }
            for lane in 0..n {
                attend(kv, 0, li, pos_base + lane, &q[lane], &mut attn_out[lane], c);
            }
            layer.wo.forward_batch(&attn_out, &mut proj);
            for lane in 0..n {
                for (hv, p) in h[lane].iter_mut().zip(&proj[lane]) {
                    *hv += p;
                }
            }

            // --- MLP ---
            for lane in 0..n {
                rmsnorm(&h[lane], &layer.mlp_norm, c.norm_eps, &mut normed[lane]);
            }
            layer.w_gate.forward_batch(&normed, &mut gate);
            layer.w_up.forward_batch(&normed, &mut up);
            for lane in 0..n {
                for (g, u) in gate[lane].iter_mut().zip(&up[lane]) {
                    *g = silu(*g) * u;
                }
            }
            layer.w_down.forward_batch(&gate, &mut down);
            for lane in 0..n {
                for (hv, dn) in h[lane].iter_mut().zip(&down[lane]) {
                    *hv += dn;
                }
            }
        }

        // Only the last position's logits are ever consumed (the chunk's
        // other next-token distributions are teacher-forced away).
        let last = h[n - 1].clone();
        let mut final_h = vec![0.0f32; d];
        rmsnorm(&last, &self.final_norm, c.norm_eps, &mut final_h);
        let mut logits = vec![0.0f32; c.vocab];
        self.lm_head.forward(&final_h, &mut logits);
        logits
    }

    /// Teacher-forced logits over a whole sequence: `logits[t]` predicts
    /// `tokens[t+1]`. Used for perplexity.
    pub fn forward_seq(&self, tokens: &[usize]) -> Vec<Vec<f32>> {
        let mut cache = KvCache::new(&self.cfg, tokens.len());
        tokens
            .iter()
            .enumerate()
            .map(|(pos, &t)| self.forward_token(t, pos, &mut cache))
            .collect()
    }

    /// Return a copy with every projection planned for `cfg` at
    /// `chunk`-token prefill slices (embeddings and norms stay fp32,
    /// standard practice). Each projection gets exactly one
    /// `UnifiedLayerPlan` — one tiling search, one weight buffer, one set
    /// of dequant tables serving both phases.
    pub fn planned_for(
        &self,
        cfg: &NpuConfig,
        chunk: usize,
        dtype: WeightDtype,
        gran: Granularity,
        use_gptq: bool,
    ) -> Transformer {
        let mut out = self.clone();
        for l in out.layers.iter_mut() {
            for lin in [
                &mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.w_gate, &mut l.w_up,
                &mut l.w_down,
            ] {
                *lin = lin.planned(cfg, chunk, dtype, gran, use_gptq);
            }
        }
        out.lm_head = out.lm_head.planned(cfg, chunk, dtype, gran, use_gptq);
        out
    }

    /// [`Transformer::planned_for`] against the default deployment target —
    /// the accuracy experiments' entry point (numerics are independent of
    /// the planned tiling).
    pub fn quantized(&self, dtype: WeightDtype, gran: Granularity, use_gptq: bool) -> Transformer {
        self.planned_for(&NpuConfig::sd8gen3(), DEFAULT_PLAN_CHUNK, dtype, gran, use_gptq)
    }

    /// Total bytes of projection weights under the current representation.
    pub fn projection_bytes(&self) -> usize {
        let lin_bytes = |l: &Linear| match l {
            Linear::F32 { w, .. } => w.len() * 4,
            Linear::Planned(p) => p.footprint_bytes(),
        };
        let mut total = lin_bytes(&self.lm_head);
        for l in &self.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                total += lin_bytes(lin);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::random_transformer;
    use crate::util::Rng;

    #[test]
    fn rmsnorm_unit_gain() {
        let x = vec![3.0f32, -4.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, &g, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] + 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut x = vec![1.0f32, 2.0, -0.5, 0.3];
        let orig = x.clone();
        rope(&mut x, 0, 10000.0);
        assert_eq!(x, orig, "pos 0 must be identity");
        rope(&mut x, 7, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-4, "rotation must preserve norm");
        assert!(x != orig);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0, -100.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0] && x[0] > x[3]);
    }

    #[test]
    fn forward_token_deterministic_and_shaped() {
        let model = random_transformer(&ModelConfig::tiny(), 42);
        let mut c1 = KvCache::new(&model.cfg, 8);
        let mut c2 = KvCache::new(&model.cfg, 8);
        let l1 = model.forward_token(65, 0, &mut c1);
        let l2 = model.forward_token(65, 0, &mut c2);
        assert_eq!(l1.len(), 256);
        assert_eq!(l1, l2);
        assert!(l1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn context_changes_predictions() {
        let model = random_transformer(&ModelConfig::tiny(), 42);
        let mut cache = KvCache::new(&model.cfg, 8);
        let a = model.forward_token(65, 0, &mut cache);
        let b = model.forward_token(65, 1, &mut cache);
        // Same token, different position/context -> different logits.
        assert!(a != b);
    }

    #[test]
    fn forward_seq_matches_incremental() {
        let model = random_transformer(&ModelConfig::tiny(), 7);
        let tokens = vec![10usize, 20, 30, 40];
        let seq = model.forward_seq(&tokens);
        let mut cache = KvCache::new(&model.cfg, 4);
        for (pos, &t) in tokens.iter().enumerate() {
            let inc = model.forward_token(t, pos, &mut cache);
            assert_eq!(seq[pos], inc, "pos {pos}");
        }
    }

    #[test]
    fn forward_batch_is_bit_identical_to_solo_forwards() {
        // The shared-weight-pass batched forward must not perturb numerics:
        // each lane's logits are byte-identical to forward_token against
        // the same cache — for fp32 and quantized projections alike.
        for quantize in [false, true] {
            let mut model = random_transformer(&ModelConfig::tiny(), 17);
            if quantize {
                model = model.quantized(WeightDtype::Int4, Granularity::PerBlock(64), false);
            }
            // Lanes at different positions with different histories.
            let histories: [&[usize]; 3] = [&[65, 66], &[90], &[12, 34, 56]];
            let mut caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&model.cfg, 16)).collect();
            let mut solo_caches: Vec<KvCache> =
                (0..3).map(|_| KvCache::new(&model.cfg, 16)).collect();
            for (lane, hist) in histories.iter().enumerate() {
                for (pos, &t) in hist.iter().enumerate() {
                    model.forward_token(t, pos, &mut caches[lane]);
                    model.forward_token(t, pos, &mut solo_caches[lane]);
                }
            }
            let steps: Vec<(usize, usize)> = histories
                .iter()
                .enumerate()
                .map(|(lane, h)| (100 + lane, h.len()))
                .collect();
            let mut cache_refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let batched = model.forward_batch(&steps, &mut cache_refs);
            for (lane, &(tok, pos)) in steps.iter().enumerate() {
                let solo = model.forward_token(tok, pos, &mut solo_caches[lane]);
                assert_eq!(batched[lane], solo, "quantize={quantize} lane {lane}");
            }
            // The batched step advanced the caches exactly like solo steps.
            for (a, b) in caches.iter().zip(&solo_caches) {
                assert_eq!(a.len, b.len);
            }
        }
    }

    #[test]
    fn forward_chunk_is_bit_identical_to_stepwise() {
        // The planned prefill pass must not perturb numerics: chunked
        // forward (weights streamed once per chunk) lands on byte-identical
        // logits to token-by-token teacher forcing — fp32 and planned
        // quantized projections alike, across a ragged chunk boundary.
        for quantize in [false, true] {
            let mut model = random_transformer(&ModelConfig::tiny(), 31);
            if quantize {
                model = model.quantized(WeightDtype::Int4, Granularity::PerBlock(64), false);
            }
            let toks = [1usize, 5, 9, 200, 42, 7];
            let mut c1 = KvCache::new(&model.cfg, 16);
            let mut want = Vec::new();
            for (pos, &t) in toks.iter().enumerate() {
                want = model.forward_token(t, pos, &mut c1);
            }
            let mut c2 = KvCache::new(&model.cfg, 16);
            model.forward_chunk(&toks[..4], 0, &mut c2);
            let got = model.forward_chunk(&toks[4..], 4, &mut c2);
            assert_eq!(got, want, "quantize={quantize}");
            assert_eq!(c1.len, c2.len);
        }
    }

    #[test]
    fn forward_batch_of_one_matches_forward_token() {
        let model = random_transformer(&ModelConfig::tiny(), 23);
        let mut c1 = KvCache::new(&model.cfg, 8);
        let mut c2 = KvCache::new(&model.cfg, 8);
        let mut refs: Vec<&mut KvCache> = vec![&mut c1];
        let batched = model.forward_batch(&[(65, 0)], &mut refs);
        let solo = model.forward_token(65, 0, &mut c2);
        assert_eq!(batched[0], solo);
    }

    #[test]
    fn linear_forward_batch_matches_forward() {
        let mut rng = Rng::new(5);
        let (m, k) = (12, 40);
        let lin = Linear::F32 { w: rng.normal_vec(m * k, 0.3), m, k };
        let qlin = lin.quantized(WeightDtype::Int4, Granularity::PerChannel, false);
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(k, 1.0)).collect();
        for l in [&lin, &qlin] {
            let mut ys = vec![vec![0.0f32; m]; 3];
            l.forward_batch(&xs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut want = vec![0.0f32; m];
                l.forward(x, &mut want);
                assert_eq!(*y, want);
            }
        }
    }

    #[test]
    fn quantized_model_stays_close_w4() {
        let model = random_transformer(&ModelConfig::tiny(), 9);
        let q = model.quantized(WeightDtype::Int4, Granularity::PerBlock(64), false);
        let tokens = vec![1usize, 2, 3];
        let lf = model.forward_seq(&tokens);
        let lq = q.forward_seq(&tokens);
        let err = crate::util::rel_l2(&lq[2], &lf[2]);
        assert!(err < 0.35, "W4 logits rel err {err}");
        assert!(q.projection_bytes() < model.projection_bytes() / 6);
    }

    #[test]
    fn linear_quant_matches_f32_forward_on_grid() {
        // Weights exactly on the quant grid: quantized forward == f32.
        let mut rng = Rng::new(3);
        let (m, k) = (8, 32);
        let mut w: Vec<f32> = (0..m * k).map(|_| (rng.below(16) as f32 - 8.0) * 0.25).collect();
        // Pin each row's extremes so the per-channel grid is exactly the
        // 0.25-spaced lattice the weights live on.
        for i in 0..m {
            w[i * k] = -2.0;
            w[i * k + 1] = 1.75;
        }
        let lin = Linear::F32 { w: w.clone(), m, k };
        let qlin = lin.quantized(WeightDtype::Int4, Granularity::PerChannel, false);
        let x = rng.normal_vec(k, 1.0);
        let mut y1 = vec![0.0f32; m];
        let mut y2 = vec![0.0f32; m];
        lin.forward(&x, &mut y1);
        qlin.forward(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }
}
